"""Loop-aware static analysis of partitioned HLO text.

``compiled.cost_analysis()`` on the CPU backend counts every ``while`` body
ONCE, ignoring trip counts - useless for scanned-layer models.  This module
re-derives the roofline quantities from ``compiled.as_text()`` *correctly*:

* computations are parsed into per-op records with resolved operand shapes
  (symbol table per computation; operand types are not inline in modern HLO);
* ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` -
  bodies are accumulated recursively x trip count;
* ``fusion``/``call``/``conditional`` recurse x1 (fusion interiors count for
  FLOPs - dots can be fused - but their *traffic* is the fusion's operands +
  outputs, matching one-kernel-one-HBM-pass semantics);
* FLOPs: ``dot`` = 2 x batch x M x N x K from the printed dimension numbers;
  ``convolution`` approximated from output x kernel volume; elementwise and
  reductions are counted 1 flop/output element (sub-1% for LM workloads);
* traffic: per top-level op, operand bytes + output bytes (a fused-kernel
  HBM model - intra-fusion temporaries are free, weights re-read per use);
* collectives: output bytes per (all-gather | all-reduce | reduce-scatter |
  all-to-all | collective-permute), '-done' halves of async pairs skipped.

All quantities are PER-DEVICE (the module is the post-SPMD partitioned
program).  This is the data source for EXPERIMENTS.md §Roofline.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from functools import lru_cache

__all__ = ["HloCosts", "analyze_hlo", "op_census"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
_OPNAME_RE = re.compile(r"^((?:\([^()]*\))|(?:[\w\[\],{}]+))\s+([\w\-]+)\(")
_TRIP_RE = re.compile(r"known_trip_count[\"':{\s]+n[\"':\s]+(\d+)")
_CALLS_RE = re.compile(r"(?:calls|body|to_apply)=%([\w.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_info(type_str: str) -> tuple[int, int]:
    """-> (bytes, n_elements) summed over a (possibly tuple) type string."""
    total_b = 0
    total_n = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total_b += n * DTYPE_BYTES[dtype]
        total_n += n
    return total_b, total_n


def _dims_of(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    dot_flops: float = 0.0
    # optional per-op-name traffic tally (kind -> bytes), for diagnostics
    traffic_by_op: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCosts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.dot_flops += other.dot_flops * mult
        for k, v in other.collective_by_kind.items():
            self.collective_by_kind[k] += v * mult
        for k, v in other.traffic_by_op.items():
            self.traffic_by_op[k] += v * mult


@dataclasses.dataclass
class _Op:
    name: str
    kind: str
    out_type: str
    out_bytes: int
    out_elems: int
    operands: list[str]
    line: str


class _Computation:
    def __init__(self, name: str):
        self.name = name
        self.ops: list[_Op] = []
        self.symbols: dict[str, str] = {}  # %name -> type str
        self.root_kind: str | None = None  # kind of the ROOT op
        self.has_dus: bool = False         # contains dynamic-update-slice
        self.has_dslice: bool = False      # contains dynamic-slice
        self._op_kinds: set = None or set()

    @property
    def pure_convert(self) -> bool:
        """True if the computation only converts dtypes (XLA:CPU inserts
        bf16->f32 converts to legalize bf16 dots; the TPU MXU consumes bf16
        directly, so these moves do not exist on the target - excluded
        from the traffic model, see DESIGN.md §7)."""
        real = self._op_kinds - {"parameter", "tuple", "get-tuple-element",
                                 "bitcast", "copy"}
        return bool(real) and real <= {"convert"}


def _parse(text: str) -> tuple[dict[str, _Computation], str | None]:
    comps: dict[str, _Computation] = {}
    entry: str | None = None
    cur: _Computation | None = None
    for raw in text.splitlines():
        line = raw.rstrip()
        header = _COMP_RE.match(line)
        if header and line.endswith("{"):
            cur = _Computation(header.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            # parameter symbol table from the header
            for pname, ptype in re.findall(r"([\w.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                           header.group(2)):
                cur.symbols[pname] = ptype
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rest = d.group(1), d.group(2)
        is_root = line.lstrip().startswith("ROOT")
        m = _OPNAME_RE.match(rest)
        if not m:
            # e.g. "%x = f32[2]{0} constant({...})" matches; params don't
            cur.symbols[name] = rest.split()[0]
            continue
        out_type, kind = m.group(1), m.group(2)
        cur.symbols[name] = out_type
        if is_root:
            cur.root_kind = kind
        if kind in ("dynamic-update-slice", "scatter"):
            cur.has_dus = True
        if kind in ("dynamic-slice", "gather", "slice"):
            cur.has_dslice = True
        cur._op_kinds.add(kind)
        ob, oe = _shape_info(out_type)
        # operands: %refs inside the top-level parens only (cheap approx:
        # refs before any attribute comma block; attributes also contain
        # %comp names - filtered later by symbol-table membership)
        call_part = rest[m.end() - 1:]
        operands = _OPERAND_RE.findall(call_part.split("),", 1)[0])
        cur.ops.append(_Op(name=name, kind=kind, out_type=out_type,
                           out_bytes=ob, out_elems=oe, operands=operands,
                           line=rest))
    return comps, entry


def _dot_flops(op: _Op, comp: _Computation) -> float:
    """2 * batch * M * N * K from printed dimension numbers."""
    lhs_t = comp.symbols.get(op.operands[0], "") if op.operands else ""
    rhs_t = comp.symbols.get(op.operands[1], "") if len(op.operands) > 1 \
        else ""
    lhs, rhs = _dims_of(lhs_t), _dims_of(rhs_t)
    if not lhs or not rhs:
        # fall back: 2 * out_elems (severe undercount; rare)
        return 2.0 * op.out_elems

    def dims(attr):
        m = re.search(attr + r"=\{([\d,]*)\}", op.line)
        if not m or not m.group(1):
            return []
        return [int(x) for x in m.group(1).split(",")]

    lc = dims("lhs_contracting_dims")
    lb = dims("lhs_batch_dims")
    k = 1
    for d in lc:
        k *= lhs[d] if d < len(lhs) else 1
    batch = 1
    for d in lb:
        batch *= lhs[d] if d < len(lhs) else 1
    m_sz = 1
    for i, d in enumerate(lhs):
        if i not in lc and i not in lb:
            m_sz *= d
    rc = dims("rhs_contracting_dims")
    rb = dims("rhs_batch_dims")
    n_sz = 1
    for i, d in enumerate(rhs):
        if i not in rc and i not in rb:
            n_sz *= d
    return 2.0 * batch * m_sz * n_sz * k


def _op_tag(op: _Op) -> str:
    m = re.search(r'op_name="([^"]*)"', op.line)
    src = "/".join(m.group(1).split("/")[-2:]) if m else ""
    return f"{op.kind}:{src}:{op.out_type[:60]}"


def op_census(text: str, kinds: tuple = ("gather",)) -> list[dict]:
    """Structural census of ops across ALL computations of an HLO module.

    Unlike :func:`analyze_hlo` this counts each TEXTUAL op exactly once -
    no trip-count multiplication, fusion interiors included - which is what
    layout regressions care about ("the compiled step contains exactly one
    ring-sized gather", not "the gather runs N times").  Returns one record
    per matching op::

        {kind, name, computation, out_type, out_elems,
         operand_types: [str], operand_elems: [int]}

    ``operand_*`` resolve through the computation's symbol table; operands
    whose type is unknown (e.g. cross-computation refs) report 0 elements.
    """
    comps, _ = _parse(text)
    recs = []
    for comp in comps.values():
        for op in comp.ops:
            if kinds and op.kind not in kinds:
                continue
            otypes = [comp.symbols.get(o, "") for o in op.operands]
            recs.append(dict(
                kind=op.kind, name=op.name, computation=comp.name,
                out_type=op.out_type, out_elems=op.out_elems,
                operand_types=otypes,
                operand_elems=[_shape_info(t)[1] for t in otypes]))
    return recs


def analyze_hlo(text: str) -> HloCosts:
    comps, entry = _parse(text)

    memo: dict[str, HloCosts] = {}

    def eval_comp(name: str, *, traffic: bool) -> HloCosts:
        key = f"{name}:{traffic}"
        if key in memo:
            return memo[key]
        total = HloCosts()
        comp = comps.get(name)
        if comp is None:
            memo[key] = total
            return total
        memo[key] = total  # break cycles defensively
        for op in comp.ops:
            kind = op.kind
            called = _CALLS_RE.findall(op.line)
            if kind == "while":
                m = _TRIP_RE.search(op.line)
                n = int(m.group(1)) if m else 1
                body = re.search(r"body=%([\w.\-]+)", op.line)
                if body:
                    total.add(eval_comp(body.group(1), traffic=traffic), n)
                continue
            if kind == "conditional":
                branches = _COND_BRANCH_RE.search(op.line)
                names = (re.findall(r"%([\w.\-]+)", branches.group(1))
                         if branches else called)
                for b in names:
                    total.add(eval_comp(b, traffic=traffic), 1.0)
                continue
            if kind in ("fusion", "call", "async-start"):
                for c in called:
                    # fusion interior: flops yes, traffic no
                    total.add(eval_comp(c, traffic=False), 1.0)
                if traffic:
                    opb = [_shape_info(comp.symbols.get(o, ""))[0]
                           for o in op.operands]
                    # In-place dynamic-update-slice / scatter fusions
                    # (incl. multi-output tuples of them): every output
                    # component with a size-matching operand is aliased -
                    # only the update slices move.
                    callee = comps.get(called[0]) if called else None
                    if callee is not None and callee.pure_convert:
                        continue  # CPU bf16-legalization convert: free on TPU
                    if callee is not None and callee.has_dslice:
                        # slicing fusion: an operand much larger than the
                        # output is only touched slice-wise
                        opb = [min(o, 2 * op.out_bytes) if
                               o > 4 * op.out_bytes else o for o in opb]
                    tb = op.out_bytes + sum(opb)
                    if callee is not None and callee.has_dus:
                        out_sizes = [
                            _shape_info(f"{dt}[{dims}]")[0]
                            for dt, dims in _SHAPE_RE.findall(op.out_type)]
                        pool = sorted(opb, reverse=True)
                        aliased = 0
                        for c in sorted(out_sizes, reverse=True):
                            if pool and pool[0] == c and c > 0:
                                aliased += c
                                pool.pop(0)
                        tb = max(tb - 2.0 * aliased, 0.0)
                    total.traffic_bytes += tb
                    total.traffic_by_op[_op_tag(op)] += tb
                continue
            # plain op
            base_kind = kind.replace("-start", "")
            if base_kind in COLLECTIVES and not kind.endswith("-done"):
                total.collective_bytes += op.out_bytes
                total.collective_by_kind[base_kind] += op.out_bytes
            if kind == "dot":
                f = _dot_flops(op, comp)
                total.flops += f
                total.dot_flops += f
            elif kind == "convolution":
                total.flops += 2.0 * op.out_elems * 8  # kernel-volume approx
            elif kind in ("add", "multiply", "subtract", "divide", "tanh",
                          "exponential", "log", "rsqrt", "sqrt", "power",
                          "maximum", "minimum", "compare", "select",
                          "reduce", "exponential-minus-one"):
                total.flops += float(op.out_elems)
            if traffic and kind not in ("parameter", "constant",
                                        "get-tuple-element", "tuple",
                                        "bitcast", "convert"):
                opb = [_shape_info(comp.symbols.get(o, ""))[0]
                       for o in op.operands]
                if kind in ("dynamic-update-slice", "scatter") and opb \
                        and max(opb) >= op.out_bytes:
                    tb = 2.0 * (sum(opb) - max(opb))  # in-place update
                elif kind in ("dynamic-slice", "slice", "gather"):
                    small = sum(o for o in opb if o <= 4 * op.out_bytes)
                    tb = 2.0 * op.out_bytes + small  # slice-wise read
                else:
                    tb = op.out_bytes + sum(opb)
                total.traffic_bytes += tb
                total.traffic_by_op[_op_tag(op)] += tb
            # reduce etc. with to_apply tiny computations: skip recursion
        memo[key] = total
        return total

    if entry is None:  # fall back: conventional name, else last computation
        for name in comps:
            if name.startswith("main"):
                entry = name
        entry = entry or (list(comps)[-1] if comps else "")
    return eval_comp(entry, traffic=True)

"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; only the dry-run process
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before its
first jax import.

Axis semantics (DESIGN.md §6):
    pod    - data parallel across pods; only gradient all-reduce crosses it
    data   - batch sharding + FSDP within a pod (+ sequence-sharded KV for
             the 500k decode cells)
    model  - tensor/expert parallelism (heads, ffn-hidden, vocab, experts)
For the SNN engine the same axes carry the paper's decomposition:
(pod, data) rows = Area-Processes groups, model = multisection cells.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh", "make_snn_host_mesh",
           "POD_SHAPE", "SINGLE_POD_SHAPE"]

SINGLE_POD_SHAPE = (16, 16)              # 256 chips (one v5e pod)
POD_SHAPE = (2, 16, 16)                  # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small host-device mesh for CPU integration tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=prod(shape))."""
    return jax.make_mesh(shape, axes)


def make_snn_host_mesh(n_rows: int, row_width: int):
    """Host-ALIGNED (rows, model) mesh for the multi-host SNN engine:
    Area-Processes rows land on single hosts, so the intra-row spike
    bitmap gather never crosses the inter-host fabric (DESIGN.md §11).
    Works single- and multi-process; validates the alignment."""
    from repro.core.multihost import make_host_mesh
    return make_host_mesh(n_rows, row_width)

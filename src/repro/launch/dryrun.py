import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above run before ANY other import - jax locks the device count
at first init, and the dry-run needs 512 placeholder host devices to build
the production meshes.  (Tests/benches import this module lazily and keep
their own 1-device world; ``setdefault`` keeps an operator override.)

For every cell this driver:
  1. builds the step function (train / prefill / decode per the shape kind),
  2. attaches shardings (params via rules, batch via batch_spec, caches via
     cache_specs) to ShapeDtypeStruct stand-ins - no real allocation,
  3. ``jit(...).lower(...).compile()`` under the mesh,
  4. records ``memory_analysis()`` (proves the per-device footprint),
     ``cost_analysis()`` (FLOPs / bytes for §Roofline) and the
     collective-bytes histogram parsed from the partitioned HLO.

Results go to ``experiments/dryrun_<mesh>.json`` and feed
EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
        [--multi-pod] [--all]
"""

import argparse
import dataclasses
import json
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.sharding import rules
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state
from repro.utils.hlo import collective_bytes

__all__ = ["input_specs", "build_cell", "run_cell", "train_config_for",
           "DEFAULT_RESULT_DIR"]

DEFAULT_RESULT_DIR = "experiments"


def train_config_for(cfg: ModelConfig) -> TrainConfig:
    """Per-arch optimizer policy: AdamW fp32 everywhere except the 671B
    (Adafactor + bf16 params - fp32 AdamW state cannot fit 256x16GB;
    EXPERIMENTS.md §Dry-run)."""
    if cfg.moe is not None:
        # MoE: expert weights are expert-RESIDENT (replicated over the
        # axes E doesn't cover), so fp32 AdamW state would replicate too -
        # Adafactor + bf16 params keeps the resident copy affordable
        # (deepseek additionally needs bf16 grad accumulation).
        return TrainConfig(optimizer="adafactor", param_dtype="bfloat16",
                           acc_dtype="bfloat16")
    # NOTE §Perf iteration 4 (refuted): gather_once=True did not reduce
    # collective bytes - XLA already hoists the loop-invariant param
    # all-gathers out of the microbatch scan; the flag remains available
    # for TPU-side validation.
    return TrainConfig(optimizer="adamw", param_dtype="float32")


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    ctx = rules.MeshCtx(mesh)
    bspec = (rules.batch_spec(mesh)
             if b % max(ctx.axis_size("batch"), 1) == 0 else P())
    bs = NamedSharding(mesh, bspec)
    out: dict[str, Any] = {}
    if shape.kind == "train":
        out["tokens"] = _sds((b, shape.seq_len + 1), jnp.int32, bs)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((b, shape.seq_len), jnp.int32, bs)
    elif shape.kind == "decode":
        out["token"] = _sds((b,), jnp.int32, bs)
        out["pos"] = _sds((b,), jnp.int32, bs)
    if cfg.family == "audio" and shape.kind in ("train", "prefill"):
        out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                             jnp.dtype(cfg.dtype), bs)
    if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
        out["patches"] = _sds((b, cfg.n_prefix_embeds, cfg.d_model),
                              jnp.dtype(cfg.dtype), bs)
    return out


def _with_shardings(tree_sds, tree_shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_sds, tree_shardings)


def _microbatches(shape: ShapeConfig, mesh) -> int:
    ctx = rules.MeshCtx(mesh)
    bsz = ctx.axis_size("batch")
    return max(1, min(shape.microbatches, shape.global_batch // bsz))


def _shardings_of(tree_sds):
    return jax.tree.map(lambda s: s.sharding, tree_sds)


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (fn, args_sds, donate, out_shardings) - ready for
    jit(fn, out_shardings=...).lower(*args_sds)."""
    model = build_model(cfg)
    params_sds = jax.eval_shape(
        lambda: model.init(jax.random.key(0),
                           dtype=jnp.dtype(train_config_for(cfg).param_dtype
                                           if shape.kind == "train"
                                           else cfg.dtype)))
    params_sh = rules.param_specs(mesh, params_sds)
    params_sds = _with_shardings(params_sds, params_sh)
    batch_sds = input_specs(cfg, shape, mesh)

    if shape.kind == "train":
        tcfg = train_config_for(cfg)
        mbs = _microbatches(shape, mesh)
        step_fn = make_train_step(model, tcfg, microbatches=mbs)
        opt_sds = jax.eval_shape(lambda p: init_opt_state(tcfg, p),
                                 params_sds)
        opt_sh = rules.param_specs(mesh, opt_sds)
        opt_sds = _with_shardings(opt_sds, opt_sh)
        step_sds = _sds((), jnp.int32, NamedSharding(mesh, P()))

        def fn(params, opt_state, batch, step):
            with rules.use_mesh(mesh):
                return step_fn(params, opt_state, batch, step)

        # params/opt outputs inherit input shardings through the update
        # chain; metrics are scalars - let XLA infer all train outputs.
        return fn, (params_sds, opt_sds, batch_sds, step_sds), (0, 1), None

    seq = shape.seq_len
    if cfg.family == "vlm":
        seq += cfg.n_prefix_embeds  # prefix patch embeds occupy cache slots
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, seq,
                                 dtype=jnp.bfloat16))
    seq_shard = shape.global_batch == 1
    cache_sh = rules.cache_specs(mesh, cache_sds, seq_shard=seq_shard)
    cache_sds = _with_shardings(cache_sds, cache_sh)

    # logits (B, ...) batch-sharded only when B divides the batch axes
    ctx = rules.MeshCtx(mesh)
    bdiv = shape.global_batch % max(ctx.axis_size("batch"), 1) == 0
    logits_sh = NamedSharding(
        mesh, rules.batch_spec(mesh) if bdiv else P())
    if shape.kind == "prefill":
        def fn(params, batch, cache):
            with rules.use_mesh(mesh):
                return model.prefill(params, batch, cache)
        out_sh = (logits_sh, _shardings_of(cache_sds))
        return fn, (params_sds, batch_sds, cache_sds), (2,), out_sh

    def fn(params, cache, token, pos):
        with rules.use_mesh(mesh):
            return model.decode(params, cache, token, pos)
    out_sh = (logits_sh, _shardings_of(cache_sds))
    return fn, (params_sds, cache_sds, batch_sds["token"],
                batch_sds["pos"]), (1,), out_sh


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             mesh=None, reduced: bool = False) -> dict[str, Any]:
    """Lower + compile one cell; returns the §Dry-run record."""
    cfg = configs.get_smoke(arch) if reduced else configs.get(arch)
    shape = SHAPES[shape_name]
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
    }
    if not shape_applicable(cfg.family, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = (f"{cfg.family} family: full attention is "
                         "quadratic at 500k; sub-quadratic archs only "
                         "(DESIGN.md §4)")
        return rec
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    try:
        fn, args, donate, out_sh = build_cell(cfg, shape, mesh)
        t0 = time.time()
        with rules.use_mesh(mesh):
            lowered = jax.jit(fn, donate_argnums=donate,
                              out_shardings=out_sh).lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        if ma is not None:
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                # donated args alias outputs; live footprint per device:
                "peak_bytes": int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
            }
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else None
        if ca:
            rec["cost"] = {
                "flops": float(ca.get("flops", -1)),
                "bytes_accessed": float(ca.get("bytes accessed", -1)),
                "transcendentals": float(ca.get("transcendentals", 0)),
            }
        rec["collectives"] = collective_bytes(compiled.as_text())
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"[:500]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(configs.ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, multi_pod=mp, mesh=mesh)
                results.append(rec)
                mem = rec.get("memory", {}).get("peak_bytes", 0) / 2**30
                cps = rec.get("compile_s", "-")
                print(f"[{rec['mesh']}] {arch:22s} {shape:12s} "
                      f"{rec['status']:8s} compile={cps}s "
                      f"peak/dev={mem:.2f}GiB "
                      f"{rec.get('reason', rec.get('error', ''))[:60]}",
                      flush=True)

    os.makedirs(DEFAULT_RESULT_DIR, exist_ok=True)
    out = args.out or os.path.join(
        DEFAULT_RESULT_DIR,
        f"dryrun_{'multi' if meshes[-1] else 'single'}.json")
    with open(out, "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\nDRY-RUN: ok={n_ok} skipped={n_skip} error={n_err} -> {out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Production-scale dry-run + roofline for the CORTEX SNN engine itself.

The LM cells prove the substrate; THIS is the paper's own workload at the
paper's own scale: the marmoset benchmark's "normalized problem size 1"
(1M neurons, 3.8B synapses) and beyond, decomposed onto the production
meshes.  Graphs are never materialized - the step lowers from
ShapeDtypeStructs whose shapes come from the decomposition arithmetic
(edges/shard, mirrors/shard, boundary widths), exactly like the LM dry-run.

Reports per (scale x mesh x wire-encoding): compile ok, per-device memory,
the three roofline terms, and the spike-exchange traffic for f32 vs packed
wires (§Perf iteration on the paper's own bottleneck).

    PYTHONPATH=src python -m repro.launch.dryrun_snn
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import snn
from repro.core.distributed import (DistributedConfig, DistState,
                                    make_raw_distributed_step,
                                    wire_bytes_for_dims, wire_bytes_split)
from repro.core.wire import sparse_packed_crossover_fraction
from repro.core.engine import EngineConfig
from repro.launch.mesh import make_production_mesh
from repro.utils.hlo_analysis import analyze_hlo

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def shard_dims(n_neurons: int, n_edges: int, n_shards: int,
               row_width: int, *, max_delay: int = 64,
               remote_frac: float = 0.25, boundary_frac: float = 0.15):
    """Decomposition arithmetic -> per-shard static shapes (padded)."""
    pad = lambda n, m=128: ((n + m - 1) // m) * m
    n_local = pad(-(-n_neurons // n_shards))
    e = pad(-(-n_edges // n_shards))
    n_mirror = pad(int(n_local * (1.0 + remote_frac)))
    b_pad = pad(max(int(n_local * boundary_frac), 8))
    return dict(n_local=n_local, n_edges=e, n_mirror=n_mirror, b_pad=b_pad,
                max_delay=max_delay)


def state_and_consts_sds(dims, mesh, axes, *, compact: bool = False):
    """SDS stand-ins.  ``compact`` stores the static edge arrays in the
    narrowest dtype their range allows (u16 mirror/post ids, i8 delays and
    channels) - the edge sweep is memory-bound, so edge bytes ARE the step
    time (§Perf iteration)."""
    S = mesh.devices.size
    sh = NamedSharding(mesh, P(axes))
    nl, nm, e, b, D = (dims["n_local"], dims["n_mirror"], dims["n_edges"],
                      dims["b_pad"], dims["max_delay"])
    f32 = jnp.float32
    i32 = jnp.int32
    idx_t = jnp.uint16 if compact and nm <= 65535 else i32
    small_t = jnp.int8 if compact else i32
    sds = lambda shape, dt: jax.ShapeDtypeStruct((S,) + shape, dt,
                                                 sharding=sh)
    state = DistState(
        v_m=sds((nl,), f32), syn_ex=sds((nl,), f32), syn_in=sds((nl,), f32),
        ref_count=sds((nl,), i32), ring=sds((D, nm), f32),
        weights=sds((e,), f32), k_pre=sds((nm,), f32), k_post=sds((nl,), f32),
        prev_bits=sds((nl,), f32), t=sds((), i32),
        key=sds((2,), jnp.uint32), wire_overflow=sds((), i32),
        gate_overflow=sds((), i32))
    consts = dict(
        pre_idx=sds((e,), idx_t), post_idx=sds((e,), idx_t),
        delay=sds((e,), small_t), channel=sds((e,), small_t),
        plastic=sds((e,), jnp.bool_), weight_init=sds((e,), f32),
        group_id=sds((nl,), i32), ext_rate=sds((nl,), f32),
        ext_weight=sds((nl,), f32), mirror_src_idx=sds((nm,), idx_t),
        boundary_slots=sds((b,), idx_t), mirror_is_intra=sds((nm,), jnp.bool_),
        mirror_row_gather=sds((nm,), i32),
        mirror_remote_gather=sds((nm,), i32), mirror_src_flat=sds((nm,), i32),
    )
    return state, consts


def run_cell(scale: float, multi_pod: bool, wire: str, *, stdp: bool = True,
             compact: bool = False, overlap: bool = True,
             wire_remote: str | None = None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    S = mesh.devices.size
    n_neurons = int(1_000_000 * scale)
    n_edges = int(3_800_000_000 * scale)   # paper: 3.8B synapses at size 1
    dims = shard_dims(n_neurons, n_edges, S, mesh.shape["model"])
    from repro.core.models import HPC_STDP
    cfg = DistributedConfig(
        engine=EngineConfig(dt=0.1, stdp=HPC_STDP if stdp else None),
        comm_mode="area", overlap=overlap, axis_names=axes,
        spike_wire=wire, spike_wire_remote=wire_remote)
    groups = [snn.LIFParams(), snn.LIFParams(t_ref=1.0)]
    step = make_raw_distributed_step(mesh, groups, cfg,
                                     max_delay=dims["max_delay"],
                                     n_local=dims["n_local"],
                                     n_mirror=dims["n_mirror"])
    state_sds, consts_sds = state_and_consts_sds(dims, mesh, axes,
                                                 compact=compact)
    t0 = time.time()
    compiled = jax.jit(step, donate_argnums=(0,)).lower(
        state_sds, consts_sds).compile()
    costs = analyze_hlo(compiled.as_text())
    ma = compiled.memory_analysis()
    # analytic per-shard wire traffic from the codec itself (no graph, no
    # HLO needed - the same SpikeWire.bytes_per_step the engine accounts
    # with), vs the packed bitmap on identical dims, split by tier
    # (intra-row/-host vs the cross-row boundary hop)
    split = wire_bytes_split(
        cfg.comm_mode, wire, wire_remote, n_shards=S,
        row_width=mesh.shape["model"], n_local=dims["n_local"],
        b_pad=dims["b_pad"])
    model_bytes = split["intra"] + split["inter"]
    packed_bytes = wire_bytes_for_dims(
        cfg.comm_mode, "packed", n_shards=S, row_width=mesh.shape["model"],
        n_local=dims["n_local"], b_pad=dims["b_pad"])
    rec = dict(
        scale=scale,
        mesh="2x16x16" if multi_pod else "16x16", wire=wire,
        wire_remote=wire_remote or wire,
        compact=compact, overlap=overlap,
        n_neurons=n_neurons, n_edges_global=n_edges, **dims,
        wire_model_bytes=model_bytes,
        wire_bytes_intra=split["intra"], wire_bytes_inter=split["inter"],
        wire_vs_packed=round(model_bytes / packed_bytes, 3),
        crossover_frac=round(
            sparse_packed_crossover_fraction(dims["n_local"]), 5),
        compile_s=round(time.time() - t0, 1),
        peak_gib=round((ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
                       / 2**30, 3),
        flops_per_chip=costs.flops,
        traffic_bytes=costs.traffic_bytes,
        collective_bytes=costs.collective_bytes,
        compute_s=costs.flops / PEAK_FLOPS,
        memory_s=costs.traffic_bytes / HBM_BW,
        collective_s=costs.collective_bytes / ICI_BW,
    )
    terms = {k: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["dominant"] = max(terms, key=terms.get)
    return rec


def measure_firing_rates(*, scale: float = 0.02, steps: int = 400,
                         n_rows: int = 4, row_width: int = 2,
                         seed: int = 0) -> dict:
    """MEASURED per-row firing rates from a small materialized probe run.

    The dry-run cells never materialize a graph, so their sparse-wire
    capacity is a guess; this probe runs the hpc_benchmark verification
    network at a small scale through the single-shard engine, partitions
    the neurons with the SAME mesh decomposition the production cells
    assume, and reports the per-row per-step firing fractions - the
    quantity the sparse ``(count, ids)`` wire must be provisioned for.
    The recommended ``sparse:<rate>`` is the worst row's PEAK fraction
    with 2x headroom (first step of the ROADMAP adaptive-capacity
    follow-on: measure, then provision).
    """
    import jax as _jax

    from repro.core import builder, models
    from repro.core.distributed import mesh_decompose
    from repro.core.engine import EngineConfig as _EngineConfig
    from repro.core import engine as _engine

    spec, _ = models.hpc_benchmark(scale=scale, stdp=False)
    g = builder.build_shards(spec, builder.decompose(spec, 1))[0] \
        .device_arrays()
    table = snn.make_param_table(list(spec.groups), dt=0.1)
    cfg = _EngineConfig(dt=0.1)
    st = _engine.init_state(g, list(spec.groups), _jax.random.key(seed))
    _, spikes = _jax.jit(lambda s: _engine.run(s, g, table, cfg, steps))(st)
    s = np.asarray(spikes)[:, :spec.n_neurons]
    dec = mesh_decompose(spec, n_rows, row_width)
    row_of = np.asarray(dec.owner) // row_width
    rows = []
    for r in range(n_rows):
        sel = s[:, row_of == r]
        frac = sel.mean(axis=1) if sel.shape[1] else np.zeros(s.shape[0])
        rows.append(dict(
            row=r, n=int(sel.shape[1]),
            rate_hz=round(float(sel.mean() / (0.1e-3)), 2),
            frac_mean=round(float(frac.mean()), 6),
            frac_peak=round(float(frac.max()), 6)))
    peak = max(r["frac_peak"] for r in rows)
    recommended = round(min(max(2.0 * peak, 1e-4), 1.0), 5)
    # the same measured peak also provisions the activity-gated sweep
    # (DESIGN.md §13): the gate's worklist capacity follows the identical
    # 2x-headroom policy, reported here in post blocks on THIS probe's
    # geometry so saturation->dense fallback is predictable up front
    from repro.core import autotune
    from repro.core.layout import DEFAULT_PB
    gate_rate = autotune.recommend_gate_rate(peak)
    nb = max(-(-g.n_local // DEFAULT_PB), 1)
    cap = autotune.gate_capacity(nb, g.n_edges, gate_rate)
    return dict(probe_scale=scale, probe_steps=steps, n_rows=n_rows,
                rows=rows, frac_peak=peak,
                recommended_sparse=f"sparse:{recommended}",
                recommended_gate=f"pallas:sparse:{gate_rate:g}",
                gate_rate=gate_rate,
                gate_capacity_blocks=cap, gate_blocks_total=nb)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun_snn.json")
    ap.add_argument("--probe-scale", type=float, default=0.02,
                    help="hpc_benchmark scale of the measured firing probe")
    ap.add_argument("--probe-steps", type=int, default=400)
    args = ap.parse_args()
    results = []
    # (wire, wire_remote, compact, overlap): paper-faithful baseline ->
    # each §Perf iteration -> the final optimized config (overlap OFF once
    # the wire is packed; EXPERIMENTS.md §Perf C3) -> the sparse ID wire
    # (CORTEX's Spikes Broadcast; beats packed below the crossover firing
    # rate) -> the per-tier multi-host split (dense bitmap intra-host,
    # sparse IDs on the inter-host boundary hop; DESIGN.md §11)
    variants = (("f32", None, False, True), ("packed", None, False, True),
                ("packed", None, True, True), ("packed", None, True, False),
                ("sparse", None, True, True),
                ("packed", "sparse", True, True))
    for multi_pod in (False, True):
        for scale in (1.0, 4.0):
            for wire, wire_remote, compact, overlap in variants:
                rec = run_cell(scale, multi_pod, wire, compact=compact,
                               overlap=overlap, wire_remote=wire_remote)
                results.append(rec)
                wtag = (wire if wire_remote is None
                        else f"{wire}+{wire_remote}")
                print(f"[{'2x16x16' if multi_pod else '16x16'}] scale={scale} "
                      f"wire={wtag:13s} compact={int(compact)} "
                      f"overlap={int(overlap)} "
                      f"peak={rec['peak_gib']:.2f}GiB "
                      f"c={rec['compute_s']*1e6:8.1f}us "
                      f"m={rec['memory_s']*1e6:8.1f}us "
                      f"n={rec['collective_s']*1e6:8.1f}us "
                      f"wire_model={rec['wire_model_bytes']}B "
                      f"(intra={rec['wire_bytes_intra']}/"
                      f"inter={rec['wire_bytes_inter']}, "
                      f"{rec['wire_vs_packed']:.2f}x packed) "
                      f"dom={rec['dominant']}", flush=True)
    # packed<->sparse crossover for the marmoset-scale (scale=1) cells: the
    # per-step firing fraction (and Hz at the paper's dt) above which the
    # fixed-capacity ID wire stops beating the 1-bit bitmap
    dt_ms = 0.1
    for rec in results:
        if rec["scale"] == 1.0 and rec["wire"] == "sparse":
            frac = rec["crossover_frac"]
            print(f"[{rec['mesh']}] packed<->sparse crossover @ "
                  f"n_local={rec['n_local']}: firing fraction {frac:.4f}"
                  f"/step = {frac / (dt_ms * 1e-3):.0f} Hz at dt={dt_ms}ms "
                  f"(sparse capacity must stay below this to win)",
                  flush=True)
    # MEASURED firing next to the analytic crossover: a small materialized
    # probe run gives the per-row firing fractions the sparse wire must
    # actually carry, and the recommended "sparse:<rate>" capacity (peak
    # with 2x headroom) - so starved-wire overflow is predictable BEFORE a
    # production run instead of discovered in wire_overflow telemetry.
    probe = measure_firing_rates(scale=args.probe_scale,
                                 steps=args.probe_steps)
    for r in probe["rows"]:
        print(f"[probe] row {r['row']}: n={r['n']} rate={r['rate_hz']}Hz "
              f"frac mean={r['frac_mean']:.5f}/step "
              f"peak={r['frac_peak']:.5f}/step", flush=True)
    from repro.core.wire import get_wire
    print(f"[probe] measured peak firing fraction {probe['frac_peak']:.5f}"
          f"/step -> recommended wire '{probe['recommended_sparse']}' "
          f"(2x headroom; default 'sparse' provisions "
          f"{get_wire('sparse').max_rate:g})", flush=True)
    print(f"[probe] same peak -> recommended sweep backend "
          f"'{probe['recommended_gate']}' (gate worklist "
          f"{probe['gate_capacity_blocks']}/{probe['gate_blocks_total']} "
          f"post blocks on the probe geometry; saturation falls back to "
          f"the dense pass and counts in gate_overflow)", flush=True)
    results.append(dict(name="firing_probe", **probe))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Roofline analysis per (arch x shape x mesh) from the compiled dry-run.

Terms (TPU v5e targets; per-chip normalization - the analyzer reports the
per-device partitioned program):

    compute_term    = HLO_FLOPs_per_chip / 197 TF/s (bf16 peak)
    memory_term     = HLO_traffic_per_chip / 819 GB/s (HBM)
    collective_term = collective_bytes_per_chip / 50 GB/s (ICI per link)

FLOPs/traffic/collectives come from :mod:`repro.utils.hlo_analysis`, which
(unlike ``cost_analysis``) multiplies ``while`` trip counts - verified exact
on closed-form workloads.  MODEL_FLOPS = 6*N_active*tokens (train) or
2*N_active*tokens (prefill/decode); the ratio MODEL_FLOPS/HLO_FLOPs exposes
remat/dispatch overhead ("useful-compute fraction").

Usage:
    PYTHONPATH=src python -m repro.launch.roofline [--arch A] [--shape S]
        [--out experiments/roofline.json]
"""

import argparse
import json
import time

import jax

from repro import configs
from repro.configs.shapes import SHAPES, shape_applicable
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh
from repro.sharding import rules
from repro.utils.hlo_analysis import analyze_hlo

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

__all__ = ["roofline_cell", "model_flops", "lever_hint"]


def model_flops(cfg, shape) -> float:
    """Analytic 'useful' FLOPs for the whole step (global, all chips)."""
    _, active = cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence + KV-cache attention reads are
    # memory-side, not FLOPs-side
    return 2.0 * active * shape.global_batch


def lever_hint(dominant: str, cfg, shape) -> str:
    if dominant == "collective":
        return ("reduce resharding: fold all-gathers into the matmuls "
                "(FSDP prefetch) or widen per-collective payloads")
    if dominant == "memory":
        if shape.kind == "decode":
            return ("decode is cache-bandwidth bound: shrink KV bytes "
                    "(MLA/GQA compression, quantized cache) or batch more "
                    "sequences per chip")
        return "fuse elementwise chains / remat less, stream weights once"
    return ("compute-bound: raise MXU utilization (bigger per-chip tiles, "
            "fewer pad/transpose ops)")


def roofline_cell(arch: str, shape_name: str, mesh, *,
                  mesh_name: str = "16x16") -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not shape_applicable(cfg.family, shape_name):
        rec["status"] = "skipped"
        return rec
    t0 = time.time()
    fn, args, donate, out_sh = build_cell(cfg, shape, mesh)
    with rules.use_mesh(mesh):
        compiled = jax.jit(fn, donate_argnums=donate,
                           out_shardings=out_sh).lower(*args).compile()
    costs = analyze_hlo(compiled.as_text())
    n_chips = mesh.devices.size

    compute_s = costs.flops / PEAK_FLOPS
    memory_s = costs.traffic_bytes / HBM_BW
    collective_s = costs.collective_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape)
    hlo_global = costs.flops * n_chips
    rec.update(
        status="ok",
        analyze_s=round(time.time() - t0, 1),
        flops_per_chip=costs.flops,
        dot_flops_per_chip=costs.dot_flops,
        traffic_bytes_per_chip=costs.traffic_bytes,
        collective_bytes_per_chip=costs.collective_bytes,
        collective_by_kind={k: v for k, v in
                            costs.collective_by_kind.items()},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops=mf,
        useful_fraction=mf / hlo_global if hlo_global else 0.0,
        # roofline fraction: useful work over the time the dominant
        # bottleneck imposes (per-chip)
        roofline_fraction=(mf / n_chips / PEAK_FLOPS) / bound if bound else 0,
        lever=lever_hint(dominant, cfg, shape),
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    mesh = make_production_mesh()  # roofline table is single-pod (spec)
    archs = [args.arch] if args.arch else list(configs.ARCH_NAMES)
    shapes = [args.shape] if args.shape else list(SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            try:
                rec = roofline_cell(arch, shape, mesh)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": str(e)[:300]}
            results.append(rec)
            if rec["status"] == "ok":
                print(f"{arch:22s} {shape:12s} dom={rec['dominant']:10s} "
                      f"c={rec['compute_s']*1e3:9.2f}ms "
                      f"m={rec['memory_s']*1e3:9.2f}ms "
                      f"n={rec['collective_s']*1e3:9.2f}ms "
                      f"useful={rec['useful_fraction']:.2f} "
                      f"roofline={rec['roofline_fraction']:.2f}", flush=True)
            else:
                print(f"{arch:22s} {shape:12s} {rec['status']} "
                      f"{rec.get('error','')[:60]}", flush=True)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()

"""Local multi-process launcher + worker for the multi-host SNN backend.

One file, two roles:

* **Launcher** (no ``--process-id``): spawns N copies of itself as local
  CPU processes - each child gets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=<devices>`` and a
  shared gloo coordinator - then waits and surfaces the result JSON
  written by process 0.  This is the CI-facing proof that the multi-host
  backend works without a cluster: 2 processes x N host devices on one
  box exercise the exact same code path a Fugaku-style deployment would
  (only the launcher differs).
* **Worker** (``--process-id`` set): joins the mesh via
  :func:`repro.core.multihost.initialize`, builds the SAME spec/
  decomposition/net as every peer (deterministic from the seed), runs the
  distributed step for ``--steps``, and reports sha256 hashes of the full
  spike and voltage trajectories plus overflow telemetry and the
  intra/inter-host wire-byte split - so a 2-process run can be diffed
  bit-for-bit against a 1-process run.  ``--bench`` adds a timed
  per-step loop (the ``bench_snn --processes`` axis shells out to this).

The workload is any scenario-zoo network (``--scenario brunel`` /
``microcircuit`` / ``marmoset``; default the hpc verification case) or
the cross-model demo net for any NeuronModel (``--model izhikevich``,
DESIGN.md §12) - the record carries the scenario/model so per-model
multi-process trajectories can be pinned.

On a REAL cluster no CLI plumbing is needed: when ``--process-id`` is
absent and SLURM (``SLURM_PROCID``/``SLURM_NTASKS``/
``SLURM_STEP_NODELIST``) or k8s-style (``REPRO_COORD_ADDR``/
``REPRO_NUM_PROC``/``REPRO_PROC_ID``) env vars are present with >1
ranks, every rank runs THIS same command line and picks up its identity
from the environment (:func:`repro.core.multihost.detect_cluster_env`).

Examples::

    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --devices-per-process 4 --steps 40 --out /tmp/mh.json
    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --devices-per-process 2 --wire packed \
        --wire-remote sparse --bench --out /tmp/mh_bench.json
    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --devices-per-process 2 --scenario brunel
    srun -n 16 python -m repro.launch.multihost --scenario microcircuit
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import subprocess
import sys
import time

__all__ = ["run_launcher", "main"]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="multi-host SNN backend: local multi-process launcher")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=4,
                    help="forced host CPU devices per process")
    ap.add_argument("--row-width", type=int, default=2,
                    help="multisection cells per Area-Processes row; must "
                         "divide devices-per-process (host alignment)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="scenario scale")
    ap.add_argument("--scenario", default="hpc_benchmark",
                    help="scenario-zoo network (hpc_benchmark|brunel|"
                         "microcircuit|marmoset; repro.core.models)")
    ap.add_argument("--model", default=None,
                    help="run the cross-model demo network for this "
                         "NeuronModel (lif|izhikevich|adex|poisson) "
                         "instead of --scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--drive-boost", type=float, default=None,
                    help="multiplier on the external Poisson rates; "
                         "default 3.0 for the hpc_benchmark smoke (keeps "
                         "tiny CI-scale nets actually firing) and 1.0 for "
                         "every other scenario/model - a zoo network's "
                         "(g, eta)-style operating point must not be "
                         "silently rescaled")
    ap.add_argument("--sweep", default="flat",
                    help="execution backend (flat|bucketed|pallas|pallas:auto)")
    ap.add_argument("--wire", default="packed",
                    help="intra-host spike wire codec")
    ap.add_argument("--wire-remote", default=None,
                    help="inter-host (boundary) wire codec; default = --wire")
    ap.add_argument("--connectivity", default=None,
                    choices=("materialized", "procedural"),
                    help="override the spec's connectivity mode; "
                         "'procedural' makes every worker build ONLY its "
                         "own rows' consts (no full-network broadcast)")
    ap.add_argument("--comm-mode", default="area", choices=("area", "global"))
    ap.add_argument("--no-stdp", action="store_true")
    ap.add_argument("--no-overlap", action="store_true")
    ap.add_argument("--bench", action="store_true",
                    help="also time a per-step loop after the trajectory run")
    ap.add_argument("--out", default="experiments/multihost.json")
    ap.add_argument("--timeout", type=float, default=900.0)
    # worker-only (set by the launcher when spawning children)
    ap.add_argument("--process-id", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    return ap


# --------------------------------------------------------------------------
# launcher role
# --------------------------------------------------------------------------

def run_launcher(args: argparse.Namespace) -> dict:
    """Spawn the worker processes, wait, return process 0's result dict."""
    if args.devices_per_process % args.row_width:
        raise SystemExit(
            f"--row-width {args.row_width} must divide "
            f"--devices-per-process {args.devices_per_process} so mesh rows "
            "align to hosts")
    coordinator = f"localhost:{_free_port()}"
    src = os.path.join(os.path.dirname(__file__), "..", "..")
    env = dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count="
                  f"{args.devices_per_process}",
        PYTHONPATH=os.pathsep.join(
            p for p in (os.path.abspath(src),
                        os.environ.get("PYTHONPATH")) if p),
    )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    base = [sys.executable, "-m", "repro.launch.multihost",
            "--coordinator", coordinator]
    for k, v in vars(args).items():
        if k in ("process_id", "coordinator") or v is None or v is False:
            continue
        flag = "--" + k.replace("_", "-")
        base += [flag] if v is True else [flag, str(v)]
    procs = [subprocess.Popen(base + ["--process-id", str(i)], env=env)
             for i in range(args.processes)]
    # poll ALL workers: one crashing (e.g. a lost coordinator race) must
    # fail the launch immediately, not after its peers hit the gloo/
    # --timeout ceiling waiting for it
    deadline = time.time() + args.timeout
    pending = dict(enumerate(procs))
    failed: list[tuple[int, object]] = []
    while pending and not failed and time.time() < deadline:
        for i, p in list(pending.items()):
            rc = p.poll()
            if rc is not None:
                del pending[i]
                if rc != 0:
                    failed.append((i, rc))
        if pending and not failed:
            time.sleep(0.2)
    for i, p in pending.items():
        p.kill()
        p.wait()
        failed.append((i, "killed"))
    if failed:
        raise SystemExit(f"worker processes failed: {failed}")
    with open(args.out) as f:
        return json.load(f)


# --------------------------------------------------------------------------
# worker role
# --------------------------------------------------------------------------

def run_worker(args: argparse.Namespace) -> dict | None:
    # imports deferred so the LAUNCHER process never touches jax (the
    # children must see XLA_FLAGS before their first jax import)
    import jax
    import numpy as np

    from repro.core import backends as backends_mod
    from repro.core import engine, models, multihost
    from repro.core import distributed as dist

    multihost.initialize(coordinator_address=args.coordinator,
                         num_processes=args.processes,
                         process_id=args.process_id)
    n_rows = jax.device_count() // args.row_width
    if args.model:
        spec, stdp = models.model_demo(args.model, scale=args.scale,
                                       stdp=True)
    else:
        spec, stdp = models.get_scenario(args.scenario, scale=args.scale)
    drive_boost = args.drive_boost
    if drive_boost is None:
        drive_boost = (3.0 if not args.model
                       and args.scenario == "hpc_benchmark" else 1.0)
    import dataclasses
    if drive_boost != 1.0:
        pops = [dataclasses.replace(p, ext_rate_hz=p.ext_rate_hz
                                    * drive_boost)
                for p in spec.populations]
        spec = dataclasses.replace(spec, populations=pops)
    if args.connectivity:
        spec = dataclasses.replace(spec, connectivity=args.connectivity)
    backend = backends_mod.get_backend(args.sweep)
    dec = dist.mesh_decompose(spec, n_rows, args.row_width)
    mesh = multihost.make_host_mesh(n_rows, args.row_width)
    if spec.connectivity == "procedural":
        # O(owned rows): each worker generates only its own shards'
        # consts; peers exchange nothing but mirror-gid tables
        net = multihost.prepare_stacked_local(
            spec, dec, n_rows, args.row_width, mesh,
            with_blocked=backend.needs_blocked)
    else:
        net = dist.prepare_stacked(spec, dec, n_rows, args.row_width,
                                   with_blocked=backend.needs_blocked)
    cfg = dist.DistributedConfig(
        engine=engine.EngineConfig(dt=0.1,
                                   stdp=None if args.no_stdp else stdp,
                                   sweep=args.sweep,
                                   neuron_model=spec.neuron_model),
        comm_mode=args.comm_mode, overlap=not args.no_overlap,
        spike_wire=args.wire, spike_wire_remote=args.wire_remote)
    step, consts = multihost.make_multihost_step(net, mesh,
                                                 list(spec.groups), cfg)
    state = multihost.init_multihost_state(net, list(spec.groups), mesh,
                                           seed=args.seed, sweep=args.sweep,
                                           neuron_model=spec.neuron_model)

    t0 = time.time()
    run = jax.jit(lambda s, c: jax.lax.scan(lambda s, _: step(s, c), s,
                                            None, length=args.steps))
    final, bits = run(state, consts)
    bits_np = multihost.replicate_to_host(bits, mesh).astype(np.uint8)
    vm_np = multihost.replicate_to_host(final.v_m, mesh)
    overflow = int(multihost.replicate_to_host(final.wire_overflow,
                                               mesh).sum())
    elapsed = time.time() - t0
    sha = lambda a: hashlib.sha256(
        np.ascontiguousarray(a).tobytes()).hexdigest()
    split = dist.wire_bytes_split(
        args.comm_mode, args.wire, args.wire_remote, n_shards=net.n_shards,
        row_width=net.row_width, n_local=net.n_local, b_pad=net.b_pad)
    rec = dict(
        processes=args.processes, devices=jax.device_count(),
        n_rows=n_rows, row_width=args.row_width, steps=args.steps,
        scale=args.scale, seed=args.seed, sweep=args.sweep,
        scenario=None if args.model else args.scenario,
        model=spec.neuron_model, drive_boost=drive_boost,
        wire=args.wire, wire_remote=args.wire_remote or args.wire,
        comm_mode=args.comm_mode, overlap=not args.no_overlap,
        stdp=not args.no_stdp, connectivity=spec.connectivity,
        bits_sha256=sha(bits_np), vm_sha256=sha(vm_np),
        spiked=int(bits_np.sum()), overflow=overflow,
        wire_bytes_intra=split["intra"], wire_bytes_inter=split["inter"],
        elapsed_s=round(elapsed, 2),
    )
    if args.bench:
        jstep = jax.jit(step)
        s, _ = jstep(state, consts)
        jax.block_until_ready(s.v_m)
        reps = max(args.steps, 5)
        t0 = time.perf_counter()
        for _ in range(reps):
            s, _ = jstep(s, consts)
        jax.block_until_ready(s.v_m)
        rec["us_per_step"] = round(
            (time.perf_counter() - t0) / reps * 1e6, 2)
    if jax.process_index() == 0:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps(rec))
        return rec
    return None


def _cluster_env():
    """Jax-free peek for cluster launch env vars; the full parse lives in
    repro.core.multihost (whose import pulls in jax - fine, because a hit
    means THIS process is a worker, not the jax-free local launcher)."""
    if not (os.environ.get("REPRO_COORD_ADDR")
            or os.environ.get("SLURM_PROCID")):
        return None
    from repro.core.multihost import detect_cluster_env
    return detect_cluster_env()


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.process_id is None:
        # real-cluster launches (SLURM / k8s-style env vars) need no CLI
        # plumbing: every rank runs the same command line and picks up its
        # identity from the environment (ROADMAP multi-host follow-on)
        env = _cluster_env()
        # single-task allocations (e.g. a batch step with SLURM_PROCID=0)
        # still want the LOCAL launcher role, so only >1 ranks divert
        if env is not None and env["num_processes"] > 1:
            args.process_id = env["process_id"]
            args.processes = env["num_processes"]
            args.coordinator = args.coordinator or env["coordinator_address"]
    if args.process_id is not None:
        run_worker(args)
        return
    rec = run_launcher(args)
    print(f"[multihost] {args.processes} process(es) ok: "
          f"spiked={rec['spiked']} overflow={rec['overflow']} "
          f"bits={rec['bits_sha256'][:12]}... -> {args.out}")


if __name__ == "__main__":
    main()

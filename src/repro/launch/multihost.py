"""Local multi-process launcher + worker for the multi-host SNN backend.

One file, two roles:

* **Launcher** (no ``--process-id``): spawns N copies of itself as local
  CPU processes - each child gets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=<devices>`` and a
  shared gloo coordinator - then waits and surfaces the result JSON
  written by process 0.  This is the CI-facing proof that the multi-host
  backend works without a cluster: 2 processes x N host devices on one
  box exercise the exact same code path a Fugaku-style deployment would
  (only the launcher differs).
* **Worker** (``--process-id`` set): joins the mesh via
  :func:`repro.core.multihost.initialize`, builds the SAME spec/
  decomposition/net as every peer (deterministic from the seed), runs the
  distributed step for ``--steps``, and reports sha256 hashes of the full
  spike and voltage trajectories plus overflow telemetry and the
  intra/inter-host wire-byte split - so a 2-process run can be diffed
  bit-for-bit against a 1-process run.  ``--bench`` adds a timed
  per-step loop (the ``bench_snn --processes`` axis shells out to this).

The workload is any scenario-zoo network (``--scenario brunel`` /
``microcircuit`` / ``marmoset``; default the hpc verification case) or
the cross-model demo net for any NeuronModel (``--model izhikevich``,
DESIGN.md §12) - the record carries the scenario/model so per-model
multi-process trajectories can be pinned.

On a REAL cluster no CLI plumbing is needed: when ``--process-id`` is
absent and SLURM (``SLURM_PROCID``/``SLURM_NTASKS``/
``SLURM_STEP_NODELIST``) or k8s-style (``REPRO_COORD_ADDR``/
``REPRO_NUM_PROC``/``REPRO_PROC_ID``) env vars are present with >1
ranks, every rank runs THIS same command line and picks up its identity
from the environment (:func:`repro.core.multihost.detect_cluster_env`).

Examples::

    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --devices-per-process 4 --steps 40 --out /tmp/mh.json
    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --devices-per-process 2 --wire packed \
        --wire-remote sparse --bench --out /tmp/mh_bench.json
    PYTHONPATH=src python -m repro.launch.multihost \
        --processes 2 --devices-per-process 2 --scenario brunel
    srun -n 16 python -m repro.launch.multihost --scenario microcircuit
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import subprocess
import sys
import time

__all__ = ["run_launcher", "main"]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("localhost", 0))
        return s.getsockname()[1]


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="multi-host SNN backend: local multi-process launcher")
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=4,
                    help="forced host CPU devices per process")
    ap.add_argument("--row-width", type=int, default=2,
                    help="multisection cells per Area-Processes row; must "
                         "divide devices-per-process (host alignment)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--scale", type=float, default=0.02,
                    help="scenario scale")
    ap.add_argument("--scenario", default="hpc_benchmark",
                    help="scenario-zoo network (hpc_benchmark|brunel|"
                         "microcircuit|marmoset; repro.core.models)")
    ap.add_argument("--model", default=None,
                    help="run the cross-model demo network for this "
                         "NeuronModel (lif|izhikevich|adex|poisson) "
                         "instead of --scenario")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--drive-boost", type=float, default=None,
                    help="multiplier on the external Poisson rates; "
                         "default 3.0 for the hpc_benchmark smoke (keeps "
                         "tiny CI-scale nets actually firing) and 1.0 for "
                         "every other scenario/model - a zoo network's "
                         "(g, eta)-style operating point must not be "
                         "silently rescaled")
    ap.add_argument("--sweep", default="flat",
                    help="execution backend (flat|bucketed|pallas|pallas:auto)")
    ap.add_argument("--wire", default="packed",
                    help="intra-host spike wire codec")
    ap.add_argument("--wire-remote", default=None,
                    help="inter-host (boundary) wire codec; default = --wire")
    ap.add_argument("--connectivity", default=None,
                    choices=("materialized", "procedural"),
                    help="override the spec's connectivity mode; "
                         "'procedural' makes every worker build ONLY its "
                         "own rows' consts (no full-network broadcast)")
    ap.add_argument("--comm-mode", default="area", choices=("area", "global"))
    ap.add_argument("--no-stdp", action="store_true")
    ap.add_argument("--no-overlap", action="store_true")
    ap.add_argument("--bench", action="store_true",
                    help="also time a per-step loop after the trajectory run")
    ap.add_argument("--out", default="experiments/multihost.json")
    ap.add_argument("--timeout", type=float, default=900.0)
    # --- fault-tolerant supervised runtime (DESIGN.md §15) ---------------
    ap.add_argument("--save-every", type=int, default=None,
                    help="checkpoint every N steps and run under gang "
                         "supervision: dead/hung workers are detected, the "
                         "gang is torn down and relaunched from the latest "
                         "committed checkpoint (enables the fault-tolerant "
                         "supervised runtime)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: <out>.ckpt)")
    ap.add_argument("--keep-ckpts", type=int, default=3,
                    help="checkpoints retained by the manager's GC")
    ap.add_argument("--fault-inject", default=None,
                    help="deterministic fault specs "
                         "kind@step[:factor][#rank], comma-separated; "
                         "kinds: kill|hang|slow|ckpt-corrupt (e.g. "
                         "'kill@70#1'); $REPRO_FAULT_INJECT works too")
    ap.add_argument("--heartbeat-timeout", type=float, default=300.0,
                    help="seconds without a worker heartbeat before the "
                         "gang is declared hung and restarted")
    ap.add_argument("--max-restarts", type=int, default=3,
                    help="gang restarts before the supervisor aborts")
    ap.add_argument("--backoff", type=float, default=0.25,
                    help="initial gang-restart backoff seconds (doubles "
                         "per restart)")
    ap.add_argument("--backoff-cap", type=float, default=30.0,
                    help="ceiling on the exponential restart backoff")
    ap.add_argument("--elastic", action="store_true",
                    help="on worker loss, restart the gang on the "
                         "SURVIVING process count (elastic shrink-restart "
                         "from the same procedural checkpoint)")
    # worker-only (set by the launcher when spawning children)
    ap.add_argument("--process-id", type=int, default=None,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--heartbeat-dir", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--incarnation", type=int, default=0,
                    help=argparse.SUPPRESS)
    return ap


# --------------------------------------------------------------------------
# launcher role
# --------------------------------------------------------------------------

def _child_env(args) -> dict:
    src = os.path.join(os.path.dirname(__file__), "..", "..")
    return dict(
        os.environ,
        XLA_FLAGS="--xla_force_host_platform_device_count="
                  f"{args.devices_per_process}",
        PYTHONPATH=os.pathsep.join(
            p for p in (os.path.abspath(src),
                        os.environ.get("PYTHONPATH")) if p),
    )


def _spawn_gang(args, coordinator: str, env: dict) -> list:
    base = [sys.executable, "-m", "repro.launch.multihost",
            "--coordinator", coordinator]
    for k, v in vars(args).items():
        if k in ("process_id", "coordinator") or v is None or v is False:
            continue
        flag = "--" + k.replace("_", "-")
        base += [flag] if v is True else [flag, str(v)]
    return [subprocess.Popen(base + ["--process-id", str(i)], env=env)
            for i in range(args.processes)]


def run_launcher(args: argparse.Namespace) -> dict:
    """Spawn the worker processes, wait, return process 0's result dict."""
    if args.devices_per_process % args.row_width:
        raise SystemExit(
            f"--row-width {args.row_width} must divide "
            f"--devices-per-process {args.devices_per_process} so mesh rows "
            "align to hosts")
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    if args.save_every:
        return _run_launcher_supervised(args)
    procs = _spawn_gang(args, f"localhost:{_free_port()}", _child_env(args))
    # poll ALL workers: one crashing (e.g. a lost coordinator race) must
    # fail the launch immediately, not after its peers hit the gloo/
    # --timeout ceiling waiting for it
    deadline = time.time() + args.timeout
    pending = dict(enumerate(procs))
    failed: list[tuple[int, object]] = []
    while pending and not failed and time.time() < deadline:
        for i, p in list(pending.items()):
            rc = p.poll()
            if rc is not None:
                del pending[i]
                if rc != 0:
                    failed.append((i, rc))
        if pending and not failed:
            time.sleep(0.2)
    for i, p in pending.items():
        p.kill()
        p.wait()
        failed.append((i, "killed"))
    if failed:
        raise SystemExit(f"worker processes failed: {failed}")
    with open(args.out) as f:
        return json.load(f)


def _run_gang(args, deadline: float) -> list[tuple[int, object]]:
    """One gang incarnation: spawn, watch exits AND heartbeats.

    Returns [] on success or [(rank, why), ...] on failure, with every
    worker reaped - the caller decides restart vs abort.  Heartbeat files
    (written per step by the workers' SimulationSupervisor into this
    incarnation's private --heartbeat-dir) catch the failure mode exit
    codes cannot: a HUNG worker that never dies.
    """
    from repro.runtime.supervisor import HeartbeatFile
    procs = _spawn_gang(args, f"localhost:{_free_port()}", _child_env(args))
    spawn_t = time.time()
    pending = dict(enumerate(procs))
    failed: list[tuple[int, object]] = []
    while pending and not failed and time.time() < deadline:
        for i, p in list(pending.items()):
            rc = p.poll()
            if rc is not None:
                del pending[i]
                if rc != 0:
                    failed.append((i, rc))
        if pending and not failed and args.heartbeat_timeout:
            now = time.time()
            ages = HeartbeatFile.ages(args.heartbeat_dir, now)
            for i in pending:
                # a worker that never beat is aged from gang spawn time
                if ages.get(i, now - spawn_t) > args.heartbeat_timeout:
                    failed.append((i, "hung"))
        if pending and not failed:
            time.sleep(0.2)
    if pending and not failed:   # overall deadline hit
        failed = [(i, "timeout") for i in pending]
    # tear down the REMAINING gang: a half-dead gang cannot make progress
    # (the collectives block), so recovery is all-or-nothing
    for i, p in pending.items():
        p.kill()
        p.wait()
    return failed


def _run_launcher_supervised(args) -> dict:
    """Gang supervision: relaunch from the latest committed checkpoint.

    Detects dead (exit code) and hung (heartbeat timeout) workers, tears
    the gang down, backs off per RestartPolicy (real capped-exponential
    delays) and relaunches; workers resume from the newest readable
    checkpoint on their own.  With ``--elastic`` a lost worker shrinks the
    next incarnation to the surviving process count - the workers re-run
    the Area-Processes decomposition for the smaller mesh and remap the
    checkpoint onto it (repro.runtime.elastic.shrink_remap_state).  The
    result record gains a ``supervision`` block: restart events, per-tier
    retry counts and the actual backoff delays.
    """
    from repro.runtime import elastic
    from repro.runtime.fault import RestartPolicy
    args.ckpt_dir = args.ckpt_dir or args.out + ".ckpt"
    os.makedirs(args.ckpt_dir, exist_ok=True)
    policy = RestartPolicy(max_restarts=args.max_restarts,
                           backoff_s=args.backoff, backoff_mult=2.0,
                           backoff_cap_s=args.backoff_cap)
    events: list[str] = []
    delays: list[float] = []
    tiers = {"same": 0, "shrink": 0}
    deadline = time.time() + args.timeout
    incarnation = 0
    while True:
        args.incarnation = incarnation
        # per-incarnation heartbeat dir: a dead gang's last beats must not
        # read as liveness for the next one
        args.heartbeat_dir = os.path.join(args.ckpt_dir,
                                          f"hb_{incarnation:03d}")
        failed = _run_gang(args, deadline)
        if not failed:
            break
        events.append(
            f"fail@inc{incarnation}:"
            + ",".join(f"{r}={c}" for r, c in sorted(failed)))
        if time.time() >= deadline:
            raise SystemExit(
                f"supervised launch timed out; events={events}")
        action, delay = policy.next_action()
        if action == "abort":
            raise SystemExit(
                f"gang exceeded max restarts ({policy.max_restarts}); "
                f"events={events}")
        delays.append(delay)
        events.append(f"backoff:{delay:.6g}")
        time.sleep(delay)
        lost = {r for r, _ in failed}
        if args.elastic and args.processes > 1:
            new_p = max(args.processes - len(lost), 1)
            plan = elastic.plan_mesh(new_p * args.devices_per_process,
                                     model_width=args.row_width,
                                     prefer_pods=False)
            events.append(f"shrink:{args.processes}->{new_p}"
                          f"(mesh {plan.shape[0]}x{plan.shape[1]})")
            args.processes = new_p
            tiers["shrink"] += 1
        else:
            tiers["same"] += 1
        incarnation += 1
    with open(args.out) as f:
        rec = json.load(f)
    rec["supervision"] = dict(
        restarts=policy.restarts, incarnations=incarnation + 1,
        tiers=tiers, events=events, delays=delays,
        processes_final=args.processes, elastic=bool(args.elastic))
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


# --------------------------------------------------------------------------
# worker role
# --------------------------------------------------------------------------

def _build_spec(args):
    """Deterministic (spec, stdp, drive_boost) every rank agrees on."""
    import dataclasses

    from repro.core import models

    if args.model:
        spec, stdp = models.model_demo(args.model, scale=args.scale,
                                       stdp=True)
    else:
        spec, stdp = models.get_scenario(args.scenario, scale=args.scale)
    drive_boost = args.drive_boost
    if drive_boost is None:
        drive_boost = (3.0 if not args.model
                       and args.scenario == "hpc_benchmark" else 1.0)
    if drive_boost != 1.0:
        pops = [dataclasses.replace(p, ext_rate_hz=p.ext_rate_hz
                                    * drive_boost)
                for p in spec.populations]
        spec = dataclasses.replace(spec, populations=pops)
    if args.connectivity:
        spec = dataclasses.replace(spec, connectivity=args.connectivity)
    return spec, stdp, drive_boost


def run_worker(args: argparse.Namespace) -> dict | None:
    if args.save_every:
        return _run_worker_supervised(args)
    # imports deferred so the LAUNCHER process never touches jax (the
    # children must see XLA_FLAGS before their first jax import)
    import jax
    import numpy as np

    from repro.core import backends as backends_mod
    from repro.core import engine, multihost
    from repro.core import distributed as dist

    multihost.initialize(coordinator_address=args.coordinator,
                         num_processes=args.processes,
                         process_id=args.process_id)
    n_rows = jax.device_count() // args.row_width
    spec, stdp, drive_boost = _build_spec(args)
    backend = backends_mod.get_backend(args.sweep)
    dec = dist.mesh_decompose(spec, n_rows, args.row_width)
    mesh = multihost.make_host_mesh(n_rows, args.row_width)
    if spec.connectivity == "procedural":
        # O(owned rows): each worker generates only its own shards'
        # consts; peers exchange nothing but mirror-gid tables
        net = multihost.prepare_stacked_local(
            spec, dec, n_rows, args.row_width, mesh,
            with_blocked=backend.needs_blocked)
    else:
        net = dist.prepare_stacked(spec, dec, n_rows, args.row_width,
                                   with_blocked=backend.needs_blocked)
    cfg = dist.DistributedConfig(
        engine=engine.EngineConfig(dt=0.1,
                                   stdp=None if args.no_stdp else stdp,
                                   sweep=args.sweep,
                                   neuron_model=spec.neuron_model),
        comm_mode=args.comm_mode, overlap=not args.no_overlap,
        spike_wire=args.wire, spike_wire_remote=args.wire_remote)
    step, consts = multihost.make_multihost_step(net, mesh,
                                                 list(spec.groups), cfg)
    state = multihost.init_multihost_state(net, list(spec.groups), mesh,
                                           seed=args.seed, sweep=args.sweep,
                                           neuron_model=spec.neuron_model)

    t0 = time.time()
    run = jax.jit(lambda s, c: jax.lax.scan(lambda s, _: step(s, c), s,
                                            None, length=args.steps))
    final, bits = run(state, consts)
    bits_np = multihost.replicate_to_host(bits, mesh).astype(np.uint8)
    vm_np = multihost.replicate_to_host(final.v_m, mesh)
    overflow = int(multihost.replicate_to_host(final.wire_overflow,
                                               mesh).sum())
    elapsed = time.time() - t0
    sha = lambda a: hashlib.sha256(
        np.ascontiguousarray(a).tobytes()).hexdigest()
    split = dist.wire_bytes_split(
        args.comm_mode, args.wire, args.wire_remote, n_shards=net.n_shards,
        row_width=net.row_width, n_local=net.n_local, b_pad=net.b_pad)
    rec = dict(
        processes=args.processes, devices=jax.device_count(),
        n_rows=n_rows, row_width=args.row_width, steps=args.steps,
        scale=args.scale, seed=args.seed, sweep=args.sweep,
        scenario=None if args.model else args.scenario,
        model=spec.neuron_model, drive_boost=drive_boost,
        wire=args.wire, wire_remote=args.wire_remote or args.wire,
        comm_mode=args.comm_mode, overlap=not args.no_overlap,
        stdp=not args.no_stdp, connectivity=spec.connectivity,
        bits_sha256=sha(bits_np), vm_sha256=sha(vm_np),
        spiked=int(bits_np.sum()), overflow=overflow,
        wire_bytes_intra=split["intra"], wire_bytes_inter=split["inter"],
        elapsed_s=round(elapsed, 2),
    )
    if args.bench:
        jstep = jax.jit(step)
        s, _ = jstep(state, consts)
        jax.block_until_ready(s.v_m)
        reps = max(args.steps, 5)
        t0 = time.perf_counter()
        for _ in range(reps):
            s, _ = jstep(s, consts)
        jax.block_until_ready(s.v_m)
        rec["us_per_step"] = round(
            (time.perf_counter() - t0) / reps * 1e6, 2)
    if jax.process_index() == 0:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps(rec))
        return rec
    return None


def _run_worker_supervised(args: argparse.Namespace) -> dict | None:
    """Checkpointed, fault-injected worker under gang supervision.

    Differences from the plain worker:

    * mesh comes from :func:`repro.core.multihost.plan_elastic_mesh`
      (whatever THIS incarnation's world holds), so a shrunken gang lands
      on the smaller Area-Processes decomposition automatically;
    * the trajectory runs as a per-step jitted python loop under
      :class:`repro.runtime.supervisor.SimulationSupervisor` - heartbeat
      per step, fault injection per step, async checkpoint (full
      mesh-agnostic host snapshot + network_metadata) every
      ``--save-every`` steps;
    * on restart the worker resumes from the newest readable checkpoint:
      same topology -> overlay the snapshot's owned rows onto a fresh
      baseline; different topology -> ``elastic.shrink_remap_state``;
    * rank 0 flushes the spike-trajectory prefix atomically right before
      each checkpoint commit, so a resumed run can still report the FULL
      trajectory hash;
    * hashes are computed over GLOBAL-order arrays (``hash_order:
      "global"``) - comparable across process counts, which is what the
      shrink-restart bit-exactness contract is pinned against.
    """
    import dataclasses

    import jax
    import numpy as np

    from repro.checkpoint.manager import CheckpointManager, network_metadata
    from repro.core import backends as backends_mod
    from repro.core import engine, multihost
    from repro.core import distributed as dist
    from repro.runtime import elastic, inject
    from repro.runtime.supervisor import HeartbeatFile, SimulationSupervisor

    multihost.initialize(coordinator_address=args.coordinator,
                         num_processes=args.processes,
                         process_id=args.process_id)
    rank = jax.process_index()
    spec, stdp, drive_boost = _build_spec(args)
    backend = backends_mod.get_backend(args.sweep)
    mesh = multihost.plan_elastic_mesh(args.row_width)
    n_rows, row_width = np.asarray(mesh.devices, dtype=object).shape
    dec = dist.mesh_decompose(spec, n_rows, row_width)
    if spec.connectivity == "procedural":
        net = multihost.prepare_stacked_local(
            spec, dec, n_rows, row_width, mesh,
            with_blocked=backend.needs_blocked)
    else:
        net = dist.prepare_stacked(spec, dec, n_rows, row_width,
                                   with_blocked=backend.needs_blocked)
    cfg = dist.DistributedConfig(
        engine=engine.EngineConfig(dt=0.1,
                                   stdp=None if args.no_stdp else stdp,
                                   sweep=args.sweep,
                                   neuron_model=spec.neuron_model),
        comm_mode=args.comm_mode, overlap=not args.no_overlap,
        spike_wire=args.wire, spike_wire_remote=args.wire_remote)
    step, consts = multihost.make_multihost_step(net, mesh,
                                                 list(spec.groups), cfg)

    ckpt_dir = args.ckpt_dir or args.out + ".ckpt"
    mgr = CheckpointManager(ckpt_dir, keep=args.keep_ckpts)
    lo, hi = ((0, net.n_shards) if net.local_slice is None
              else net.local_slice)
    owner, li = dec.owner, dec.local_index()
    meta_fields = ("weights_layout", "neuron_model")

    # fresh baseline: restores OVERLAY onto it, because None/empty-dict
    # fields (drive_key, a model's empty aux) leave no checkpoint leaves
    base = dist.init_stacked_state(net, list(spec.groups), seed=args.seed,
                                   sweep=args.sweep,
                                   neuron_model=spec.neuron_model)
    carried = {"wire_overflow": 0, "gate_overflow": 0}
    resumed_from = None
    start_step = 0
    latest = mgr.latest_step()
    if latest is None:
        fields = {f.name: getattr(base, f.name)
                  for f in dataclasses.fields(base)
                  if f.name not in meta_fields}
    else:
        got, host, md = mgr.load_host()
        if md.get("sweep", args.sweep) != args.sweep:
            raise SystemExit(
                f"checkpoint at step {got} was written by sweep="
                f"{md['sweep']}, cannot resume with {args.sweep}")
        old_rows = int(md.get("n_rows", n_rows))
        old_width = int(md.get("row_width", row_width))
        if (old_rows, old_width) == (n_rows, row_width):
            fields = {}
            for f in dataclasses.fields(base):
                if f.name in meta_fields:
                    continue
                v = getattr(base, f.name)
                hv = host.get(f.name)
                if isinstance(v, dict):
                    fields[f.name] = {
                        k: (np.asarray(hv[k])[lo:hi]
                            if hv is not None and k in hv else np.array(a))
                        for k, a in v.items()}
                elif v is None or hv is None:
                    fields[f.name] = v
                else:
                    fields[f.name] = np.asarray(hv)[lo:hi]
        else:
            fields, carried = elastic.shrink_remap_state(
                spec, args.seed, host, step=got,
                old_n_rows=old_rows, old_row_width=old_width,
                new_dec=dec, new_net=net, groups=list(spec.groups),
                sweep=args.sweep, neuron_model=spec.neuron_model,
                stdp_active=not args.no_stdp)
        start_step = resumed_from = got
    state = multihost.state_from_fields(
        fields, mesh, local_slice=net.local_slice,
        weights_layout=base.weights_layout, neuron_model=base.neuron_model)

    # global-order spike trajectory, one (N,) uint8 row per step; the
    # committed prefix rides next to the checkpoints (atomic replace, not
    # GC'd) so a restarted incarnation reloads exactly the rows matching
    # its restored step
    traj_path = lambda s: os.path.join(ckpt_dir, f"traj_{s:09d}.npy")
    bits_rows: list[np.ndarray] = []
    if resumed_from:
        prefix = np.load(traj_path(resumed_from))
        if prefix.shape[0] != resumed_from:
            raise SystemExit(
                f"trajectory prefix {traj_path(resumed_from)} holds "
                f"{prefix.shape[0]} rows, checkpoint says {resumed_from}")
        bits_rows = [np.asarray(r, np.uint8) for r in prefix]

    hb = (HeartbeatFile(args.heartbeat_dir, rank)
          if args.heartbeat_dir else None)
    injector = inject.FaultInjector.from_args(
        args.fault_inject, rank=rank, mode="process",
        state_dir=os.path.join(ckpt_dir, "faults"), ckpt_dir=ckpt_dir)

    def metadata_fn(s, _state):
        return network_metadata(spec, seed=args.seed, extra=dict(
            step=s, n_rows=n_rows, row_width=row_width, sweep=args.sweep,
            neuron_model=spec.neuron_model, stdp=not args.no_stdp,
            connectivity=spec.connectivity))

    def flush_traj(s, _state):
        if rank != 0:
            return
        tmp = traj_path(s) + ".tmp"
        with open(tmp, "wb") as f:   # file object: no np.save .npy-append
            np.save(f, np.stack(bits_rows[:s]).astype(np.uint8))
        os.replace(tmp, traj_path(s))

    jstep = jax.jit(step)

    def step_fn(s, _i):
        return jstep(s, consts)

    def on_step(sstep, _state, bits):
        # replicate_to_host is a collective: every rank appends in lockstep
        b = np.asarray(multihost.replicate_to_host(bits, mesh), np.uint8)
        bits_rows.append(b[owner, li])

    sup = SimulationSupervisor(
        mgr if rank == 0 else None, save_every=args.save_every,
        heartbeat=hb, injector=injector,
        snapshot_fn=lambda s: multihost.snapshot_host_state(s, mesh),
        metadata_fn=metadata_fn, pre_save=flush_traj, restore_fn=None)
    t0 = time.time()
    final, _ = sup.run(state, step_fn, args.steps, start_step=start_step,
                       on_step=on_step)
    elapsed = time.time() - t0

    bits_all = np.stack(bits_rows).astype(np.uint8)      # (steps, N)
    vm_g = np.asarray(multihost.replicate_to_host(final.v_m, mesh))[
        owner, li]                                       # (N,) global order
    overflow = carried["wire_overflow"] + int(
        multihost.replicate_to_host(final.wire_overflow, mesh).sum())
    gate = carried["gate_overflow"] + int(
        multihost.replicate_to_host(final.gate_overflow, mesh).sum())
    if rank != 0:
        return None
    sha = lambda a: hashlib.sha256(
        np.ascontiguousarray(a).tobytes()).hexdigest()
    split = dist.wire_bytes_split(
        args.comm_mode, args.wire, args.wire_remote, n_shards=net.n_shards,
        row_width=net.row_width, n_local=net.n_local, b_pad=net.b_pad)
    rec = dict(
        processes=args.processes, devices=jax.device_count(),
        n_rows=n_rows, row_width=row_width, steps=args.steps,
        scale=args.scale, seed=args.seed, sweep=args.sweep,
        scenario=None if args.model else args.scenario,
        model=spec.neuron_model, drive_boost=drive_boost,
        wire=args.wire, wire_remote=args.wire_remote or args.wire,
        comm_mode=args.comm_mode, overlap=not args.no_overlap,
        stdp=not args.no_stdp, connectivity=spec.connectivity,
        bits_sha256=sha(bits_all), vm_sha256=sha(vm_g),
        spiked=int(bits_all.sum()), overflow=overflow,
        gate_overflow=gate,
        wire_bytes_intra=split["intra"], wire_bytes_inter=split["inter"],
        elapsed_s=round(elapsed, 2),
        # supervised-runtime extras
        hash_order="global", supervised=True, save_every=args.save_every,
        resumed_from=resumed_from, incarnation=args.incarnation,
        ckpt_events=sup.events,
    )
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec))
    return rec


def _cluster_env():
    """Jax-free peek for cluster launch env vars; the full parse lives in
    repro.core.multihost (whose import pulls in jax - fine, because a hit
    means THIS process is a worker, not the jax-free local launcher)."""
    if not (os.environ.get("REPRO_COORD_ADDR")
            or os.environ.get("SLURM_PROCID")):
        return None
    from repro.core.multihost import detect_cluster_env
    return detect_cluster_env()


def main(argv=None):
    args = build_parser().parse_args(argv)
    if args.process_id is None:
        # real-cluster launches (SLURM / k8s-style env vars) need no CLI
        # plumbing: every rank runs the same command line and picks up its
        # identity from the environment (ROADMAP multi-host follow-on)
        env = _cluster_env()
        # single-task allocations (e.g. a batch step with SLURM_PROCID=0)
        # still want the LOCAL launcher role, so only >1 ranks divert
        if env is not None and env["num_processes"] > 1:
            args.process_id = env["process_id"]
            args.processes = env["num_processes"]
            args.coordinator = args.coordinator or env["coordinator_address"]
    if args.process_id is not None:
        run_worker(args)
        return
    rec = run_launcher(args)
    print(f"[multihost] {args.processes} process(es) ok: "
          f"spiked={rec['spiked']} overflow={rec['overflow']} "
          f"bits={rec['bits_sha256'][:12]}... -> {args.out}")


if __name__ == "__main__":
    main()

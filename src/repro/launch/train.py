"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        [--smoke] [--steps 20] [--mesh 2x2] [--ckpt DIR] [--resume] \
        [--grad-compress int8_ef]

With ``--smoke`` (default on CPU) the reduced config trains for real;
without it the full config is built and the step is compiled against the
production mesh (the dry-run path) before the loop starts - on TPU pods the
same entry point is the real run.  Supervision (checkpoint/restart,
heartbeat) wraps the loop in both modes.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.models.model import build_model
from repro.runtime.fault import HeartbeatMonitor
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state


def parse_mesh(s: str | None):
    if not s:
        return None
    dims = tuple(int(x) for x in s.split("x"))
    axes = {1: ("data",), 2: ("data", "model"),
            3: ("pod", "data", "model")}[len(dims)]
    return jax.make_mesh(dims, axes)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--save-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(
        args.arch)
    m = build_model(cfg)
    tcfg = TrainConfig(optimizer=args.optimizer, lr=args.lr)
    mesh = parse_mesh(args.mesh)

    params = m.init(jax.random.key(0))
    opt = init_opt_state(tcfg, params)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    mgr = CheckpointManager(args.ckpt, keep=2) if args.ckpt else None
    monitor = HeartbeatMonitor(1)

    start = 0
    if mgr and args.resume and mgr.latest_step() is not None:
        (params, opt), meta = mgr.restore((params, opt))
        start = meta["step"]
        print(f"resumed @ {start}")

    step_fn = make_train_step(m, tcfg, microbatches=args.microbatches)
    if mesh is not None:
        from repro.sharding import rules
        mesh_cm = rules.use_mesh(mesh)
        mesh_cm.__enter__()
    jstep = jax.jit(step_fn, donate_argnums=(0, 1))

    def make_batch(i):
        b = {"tokens": jnp.asarray(pipe.batch(i)["tokens"])}
        if cfg.family == "audio":
            b["frames"] = jax.random.normal(
                jax.random.key(i), (args.batch, cfg.encoder_seq,
                                    cfg.d_model)) * 0.02
        if cfg.family == "vlm":
            b["patches"] = jax.random.normal(
                jax.random.key(i), (args.batch, cfg.n_prefix_embeds,
                                    cfg.d_model)) * 0.02
        return b

    for i in range(start, args.steps):
        t0 = time.monotonic()
        params, opt, met = jstep(params, opt, make_batch(i),
                                 jnp.asarray(i))
        monitor.observe(0, time.monotonic() - t0)
        if i % 5 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(met['loss']):.4f} "
                  f"gnorm={float(met['grad_norm']):.3f}")
        if mgr and (i + 1) % args.save_every == 0:
            mgr.save(i + 1, (params, opt), blocking=False,
                     metadata={"step": i + 1})
    if mgr:
        mgr.wait()
    if monitor.stragglers():
        print("stragglers detected:", monitor.stragglers())
    print("done")


if __name__ == "__main__":
    main()

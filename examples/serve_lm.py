"""Batched serving example: wave admission + greedy decode over KV caches.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen2.5-3b]
"""

import argparse
import sys
sys.path.insert(0, "src")

import jax

from repro import configs
from repro.models.model import build_model
from repro.serve.engine import BatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    server = BatchServer(m, params, slots=4, max_len=128, eos_id=-1)

    requests = [
        [11, 23, 5, 42],
        [7, 7, 7],
        [101, 55, 2, 9, 13, 28],
        [64],
    ]
    outs, stats = server.serve(requests, max_new_tokens=args.max_new)
    for i, o in enumerate(outs):
        print(f"req{i}: prompt={requests[i]} -> {o[:12]}...")
    print(f"prefill {stats.prefill_s*1e3:.1f} ms, "
          f"decode {stats.decode_tok_per_s:.1f} tok/s "
          f"({stats.tokens_out} tokens)")
    print("ok")


if __name__ == "__main__":
    main()

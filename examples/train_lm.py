"""End-to-end LM training driver: ~45M-param model, a few hundred steps.

Full production loop on CPU scale: deterministic resumable pipeline,
AdamW + clipping + grad accumulation, async checkpoints, supervisor-driven
restart, loss curve report.  (A ~100M+ model trains identically - pass
--d-model 768 --layers 12; CPU wall-clock is the only reason defaults are
smaller.)

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""

import argparse
import sys
sys.path.insert(0, "src")

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.models.model import build_model
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ckpt", default="/tmp/lm_ckpt")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = ModelConfig(
        name=f"lm-{args.d_model}d{args.layers}L", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=max(args.d_model // 128, 1),
        d_ff=args.d_model * 4, vocab_size=args.vocab, dtype="float32",
        tie_embeddings=True)
    m = build_model(cfg)
    total, _ = cfg.param_count()
    print(f"model {cfg.name}: ~{total/1e6:.1f}M params")

    tcfg = TrainConfig(optimizer="adamw", lr=1e-3, weight_decay=0.01)
    params = m.init(jax.random.key(0))
    opt = init_opt_state(tcfg, params)
    pipe = TokenPipeline(vocab_size=args.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=17)
    mgr = CheckpointManager(args.ckpt, keep=2)
    start = 0
    if args.resume and mgr.latest_step() is not None:
        (params, opt), meta = mgr.restore((params, opt))
        start = meta["step"]
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(m, tcfg,
                                      microbatches=args.microbatches),
                      donate_argnums=(0, 1))
    t0 = time.time()
    for i in range(start, args.steps):
        batch = {"tokens": jnp.asarray(pipe.batch(i)["tokens"])}
        params, opt, met = step_fn(params, opt, batch, jnp.asarray(i))
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(met['loss']):.4f} "
                  f"gnorm={float(met['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i-start+1):.2f}s/step)")
        if (i + 1) % 25 == 0:
            mgr.save(i + 1, (params, opt), blocking=False,
                     metadata={"step": i + 1})
    mgr.wait()
    print("ok")


if __name__ == "__main__":
    main()

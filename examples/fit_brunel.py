"""Parameter inversion demo: recover brunel's (g, eta) by gradient
descent through the simulator (DESIGN.md §17).

Builds the quick-geometry brunel network at the TRUE parameters, records
per-neuron PSTH targets at two drive conditions, then fits ``(g, eta)``
from a perturbed init: an Adam descent in log-parameter space through
the surrogate-gradient rollout, followed by an eta-profiled g scan that
pins the sharp joint minimum.  The full fit takes ~4-6 CPU minutes and
lands within 5% relative error; ``--smoke`` runs the CI-sized fit
(~1 min, looser landing).

    PYTHONPATH=src python examples/fit_brunel.py --init-g 4.0 --init-eta 2.5
    PYTHONPATH=src python examples/fit_brunel.py --smoke
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

from repro.diff import inverse


def main():
    ap = argparse.ArgumentParser(
        description="fit brunel (g, eta) from PSTH targets by gradient")
    ap.add_argument("--init-g", type=float, default=4.0,
                    help="perturbed init for g (truth: 5.0)")
    ap.add_argument("--init-eta", type=float, default=2.5,
                    help="perturbed init for eta (truth: 2.0)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized fit: shorter rollouts, one profiled "
                         "round (~1 min)")
    args = ap.parse_args()

    kwargs = {}
    if args.smoke:
        kwargs = dict(n_steps=300, adam_iters=8, g_rounds=((0.12, 5),),
                      eta_radii=(0.003, 0.001), eta_points=4)

    print(f"fitting from init (g={args.init_g}, eta={args.init_eta}) ...")
    t0 = time.perf_counter()
    res = inverse.invert_brunel(args.init_g, args.init_eta, **kwargs)
    dt = time.perf_counter() - t0

    err = res.rel_error
    print(f"  true     g={res.true_g:.4f}  eta={res.true_eta:.4f}")
    print(f"  fitted   g={res.g:.4f}  eta={res.eta:.4f}")
    print(f"  rel err  g={100 * err['g']:.2f}%  "
          f"eta={100 * err['eta']:.2f}%")
    print(f"  loss {res.loss_history[0]:.3e} -> {res.final_loss:.3e} "
          f"({res.n_evals} loss evals, {dt:.0f}s)")
    bar = 0.25 if args.smoke else 0.05
    ok = err["g"] <= bar and err["eta"] <= bar
    print("  OK" if ok else f"  MISSED the {bar:.0%} bar")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())

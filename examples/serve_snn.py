"""Multi-tenant SNN serving: N resident brunel sessions, one vmapped step.

The session engine (DESIGN.md §16) holds every tenant's state as one slot
of a fixed batch and advances all residents with ONE jitted
``vmap(engine_step)`` - the consts (graph, param table, config) are built
and compiled once, per-session cost is a slot of state.  This driver:

1. creates N sessions with different seeds (same network - one engine
   serves ONE scenario),
2. steps them interleaved - solo steps, partial waves, full waves -
   exactly as an interactive multi-tenant workload would,
3. streams each session's recent spike window and prints per-tenant rates,
4. (with --ckpt-dir) over-subscribes the slots so sessions park in the
   queue and eviction round-trips through the checkpoint manager.

    PYTHONPATH=src python examples/serve_snn.py --sessions 4 --steps 400
    PYTHONPATH=src python examples/serve_snn.py --sessions 4 --slots 2 \
        --ckpt-dir /tmp/snn_sessions --steps 400
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.core import models
from repro.serve.sessions import Backpressure
from repro.serve.snn import SessionEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=4)
    ap.add_argument("--slots", type=int, default=None,
                    help="resident slots (default: --sessions; fewer "
                         "slots + --ckpt-dir exercises eviction)")
    ap.add_argument("--steps", type=int, default=400,
                    help="steps per session (dt=0.1 ms)")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--sweep", default="flat",
                    help="execution backend: flat | bucketed | pallas")
    ap.add_argument("--ckpt-dir", default=None,
                    help="enables eviction (and slot over-subscription)")
    args = ap.parse_args()

    slots = args.slots or args.sessions
    eng = SessionEngine(max_sessions=slots, sweep=args.sweep,
                        ckpt_dir=args.ckpt_dir)
    sids = []
    for seed in range(args.sessions):
        sid = eng.create("brunel", seed=seed, scale=args.scale)
        if isinstance(sid, Backpressure):
            print(f"seed {seed}: backpressure ({sid.reason})")
            continue
        sids.append(sid)
        print(f"session {sid}: seed={seed} "
              f"status={eng.session_info(sid)['status']}")

    n = eng.graph.n_local
    print(f"\nnetwork: {n} neurons/session x {slots} slots, "
          f"backend={args.sweep}")

    # interleaved workload: a solo warmup for session 0, then half-waves,
    # then everyone in lockstep for the remainder
    eng.step(sids[0], 40)
    half = sids[:max(len(sids) // 2, 1)]
    eng.step_wave(half, n=40)
    done = {sid: eng.session_info(sid)["step"] for sid in sids}
    remaining = {sid: args.steps - done[sid] for sid in sids}
    # ragged tails: step each session to the same final step count
    for sid in sids:
        r = eng.step(sid, remaining[sid])
        if isinstance(r, Backpressure):   # parked + no eviction path
            print(f"session {sid}: backpressure ({r.reason})")

    print(f"\nper-session rates over the last {min(args.steps, 200)} "
          f"recorded steps:")
    for sid in sids:
        info = eng.session_info(sid)
        if info["step"] == 0:
            continue
        first, bits = eng.spikes(sid, window=200)
        rate = models.firing_rate_hz(np.asarray(bits, np.float32), n)
        print(f"  session {sid}: step={info['step']:>5} "
              f"status={info['status']:>8} rate={rate:6.2f} Hz "
              f"(window [{first}, {first + len(bits)}))")

    s = eng.stats()
    print(f"\nengine: slots={s['slots']} resident={s['resident']} "
          f"evicted={s['evicted']} queued={s['queued']}")


if __name__ == "__main__":
    main()

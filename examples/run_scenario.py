"""Run any scenario-zoo network x neuron model end-to-end.

The ``simulate_marmoset``-style driver for the rest of the zoo
(DESIGN.md §12): pick a scenario (``brunel`` with its (g, eta) regime
knobs, the Potjans-Diesmann ``microcircuit``, ``hpc_benchmark``,
``marmoset``) or a NeuronModel demo network (``--model izhikevich`` /
``adex`` / ``poisson``), simulate, and report per-population rates.

    PYTHONPATH=src python examples/run_scenario.py --scenario brunel \
        --scale 0.02 --g 4.5 --eta 2.0 --steps 2000
    PYTHONPATH=src python examples/run_scenario.py --scenario microcircuit \
        --scale 0.02 --steps 1000
    PYTHONPATH=src python examples/run_scenario.py --model izhikevich

With >1 host devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)
the same run goes through the distributed shard_map engine on a
(rows, width) mesh - every scenario and model rides the same decomposition,
backends, and spike wires.
"""

import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core import builder, engine, models
from repro.core import distributed as dist
from repro.core import neuron_models


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="brunel",
                    choices=models.available_scenarios())
    ap.add_argument("--model", default=None,
                    help="run the cross-model demo network for this "
                         "NeuronModel instead of --scenario "
                         f"(one of {neuron_models.available_models()})")
    ap.add_argument("--scale", type=float, default=0.02)
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--g", type=float, default=None,
                    help="brunel inhibition balance")
    ap.add_argument("--eta", type=float, default=None,
                    help="brunel external drive / threshold rate")
    ap.add_argument("--poisson-input", action="store_true",
                    help="brunel: explicit Poisson emitter population "
                         "(the lif+poisson composite) instead of the "
                         "collapsed per-neuron rate")
    ap.add_argument("--sweep", default="flat",
                    help="execution backend (flat|bucketed|pallas)")
    ap.add_argument("--spike-wire", default="packed")
    args = ap.parse_args()

    if args.model:
        spec, stdp = models.model_demo(args.model, scale=args.scale)
        tag = f"model_demo({args.model})"
    else:
        kw = {}
        if args.scenario == "brunel":
            if args.g is not None:
                kw["g"] = args.g
            if args.eta is not None:
                kw["eta"] = args.eta
            if args.poisson_input:
                kw["poisson_input"] = True
        spec, stdp = models.get_scenario(args.scenario, scale=args.scale,
                                         **kw)
        tag = args.scenario
    model = neuron_models.get_model(spec.neuron_model)
    table = model.make_param_table(list(spec.groups), dt=models.DT_MS)
    n_dev = jax.device_count()
    print(f"{tag}: {spec.n_neurons} neurons, "
          f"{len(spec.populations)} population(s), "
          f"neuron_model={spec.neuron_model}, {n_dev} device(s)")

    if n_dev > 1:
        width = 2 if n_dev % 2 == 0 else 1
        rows = n_dev // width
        mesh = jax.make_mesh((rows, width), ("data", "model"))
        dec = dist.mesh_decompose(spec, rows, width)
        net = dist.prepare_stacked(spec, dec, rows, width)
        dcfg = dist.DistributedConfig(
            engine=engine.EngineConfig(dt=models.DT_MS, stdp=stdp,
                                       sweep=args.sweep,
                                       neuron_model=spec.neuron_model),
            spike_wire=args.spike_wire)
        step, _ = dist.make_distributed_step(net, mesh, list(spec.groups),
                                             dcfg)
        state = dist.init_stacked_state(net, list(spec.groups),
                                        sweep=args.sweep,
                                        neuron_model=spec.neuron_model)
        jstep = jax.jit(step)
        counts = np.zeros(spec.n_neurons)
        for _ in range(args.steps):
            state, bits = jstep(state)
            b = np.asarray(bits)
            for si, part in enumerate(dec.parts):
                counts[part] += b[si, :part.size]
    else:
        dec = builder.decompose(spec, 1)
        g = builder.build_shards(spec, dec)[0].device_arrays()
        cfg = engine.EngineConfig(dt=models.DT_MS, stdp=stdp,
                                  sweep=args.sweep,
                                  neuron_model=spec.neuron_model)
        state = engine.init_state(g, list(spec.groups), jax.random.key(0),
                                  sweep=args.sweep,
                                  neuron_model=spec.neuron_model)
        step = engine.make_step_fn(g, table, cfg)
        counts = np.zeros(g.n_local)
        for _ in range(args.steps):
            state, bits = step(state)
            counts[:] += np.asarray(bits)
        counts = counts[:spec.n_neurons]

    t_s = args.steps * models.DT_MS * 1e-3
    off = spec.pop_offsets()
    for i, p in enumerate(spec.populations):
        r = counts[off[i]:off[i + 1]].sum() / (p.n * t_s)
        print(f"  {p.name:6s} n={p.n:7d} rate={r:8.2f} Hz")
    print(f"  total: {counts.sum():.0f} spikes over "
          f"{args.steps * models.DT_MS:.0f} ms "
          f"(mean {counts.sum() / (spec.n_neurons * t_s):.2f} Hz)")
    print("ok")


if __name__ == "__main__":
    main()

"""Quickstart: the two faces of the framework in ~a minute.

1. CORTEX SNN engine - build the balanced random network (paper §IV.A),
   simulate 200 ms, print the firing-rate band.
2. LM stack - one training step of a reduced qwen2.5 config on the
   deterministic synthetic pipeline.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import TrainConfig
from repro.core import builder, engine, models, snn
from repro.data.pipeline import TokenPipeline
from repro.models.model import build_model
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state


def snn_demo():
    print("== CORTEX SNN: balanced random network (hpc_benchmark) ==")
    spec, stdp = models.hpc_benchmark(scale=0.04, stdp=True)
    dec = builder.decompose(spec, 1)
    g = builder.build_shards(spec, dec)[0].device_arrays()
    table = snn.make_param_table(list(spec.groups), dt=models.DT_MS)
    cfg = engine.EngineConfig(dt=models.DT_MS, stdp=stdp)
    state = engine.init_state(g, list(spec.groups), jax.random.key(0))
    state, spikes = jax.jit(
        lambda s: engine.run(s, g, table, cfg, 2000))(state)
    rate = models.firing_rate_hz(np.asarray(spikes), spec.n_neurons)
    print(f"  neurons={spec.n_neurons} edges={g.n_edges} "
          f"steps=2000 (200 ms)")
    print(f"  mean rate = {rate:.2f} Hz (paper band: < 10 Hz, "
          f"asynchronous-irregular)")
    w = np.asarray(state.weights)
    print(f"  STDP weights: min={w.min():.1f} max={w.max():.1f} (bounded)")


def lm_demo():
    print("== LM stack: one train step (reduced qwen2.5) ==")
    cfg = configs.get_smoke("qwen2.5-3b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    tcfg = TrainConfig(optimizer="adamw", lr=1e-3)
    opt = init_opt_state(tcfg, params)
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=32,
                         global_batch=4)
    step = jax.jit(make_train_step(m, tcfg), donate_argnums=(0, 1))
    for i in range(3):
        batch = {"tokens": jnp.asarray(pipe.batch(i)["tokens"])}
        params, opt, met = step(params, opt, batch, jnp.asarray(i))
        print(f"  step {i}: loss={float(met['loss']):.3f} "
              f"grad_norm={float(met['grad_norm']):.3f}")


if __name__ == "__main__":
    snn_demo()
    lm_demo()
    print("ok")

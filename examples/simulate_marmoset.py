"""End-to-end driver for the paper's evaluation case (§IV.B).

Builds the multi-area marmoset-style cortical network, decomposes it with
Area-Processes Mapping + Multisection Division onto a (rows x width) layout,
runs a few hundred ms of biological time with checkpoint/restart, and
reports per-area rates + the spike-exchange traffic split (local vs remote)
that the indegree decomposition buys.

    PYTHONPATH=src python examples/simulate_marmoset.py \
        [--scale 0.002] [--areas 4] [--steps 2000] [--ckpt /tmp/marmoset]

With >1 host devices (XLA_FLAGS=--xla_force_host_platform_device_count=8)
the same script runs the shard_map engine on a (rows, width) mesh.
"""

import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core import builder, engine, models, snn
from repro.core import distributed as dist


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--areas", type=int, default=4)
    ap.add_argument("--steps", type=int, default=2000)
    ap.add_argument("--ckpt", default="/tmp/marmoset_ckpt")
    ap.add_argument("--save-every", type=int, default=500)
    ap.add_argument("--spike-wire", default="packed",
                    help="spike-exchange codec: f32|u8|packed|sparse|"
                         "sparse:<rate> (multi-device runs only)")
    ap.add_argument("--spike-wire-remote", default=None,
                    help="codec for the cross-row boundary tier (the "
                         "inter-host hop on a host-aligned mesh); "
                         "default: same as --spike-wire")
    args = ap.parse_args()

    spec = models.marmoset(scale=args.scale, n_areas=args.areas)
    n_dev = jax.device_count()
    table = snn.make_param_table(list(spec.groups), dt=models.DT_MS)
    mgr = CheckpointManager(args.ckpt, keep=2)
    print(f"marmoset: {spec.n_neurons} neurons, {args.areas} areas, "
          f"{n_dev} device(s)")

    if n_dev > 1:
        width = 2 if n_dev % 2 == 0 else 1
        rows = n_dev // width
        mesh = jax.make_mesh((rows, width), ("data", "model"))
        dec = dist.mesh_decompose(spec, rows, width)
        net = dist.prepare_stacked(spec, dec, rows, width)
        dcfg = dist.DistributedConfig(
            engine=engine.EngineConfig(dt=models.DT_MS),
            spike_wire=args.spike_wire,
            spike_wire_remote=args.spike_wire_remote)
        step, _ = dist.make_distributed_step(net, mesh, list(spec.groups),
                                             dcfg)
        state = dist.init_stacked_state(net, list(spec.groups))
        print(f"  mesh {rows}x{width}; spike traffic/step/shard: "
              f"area={net.comm_bytes_area}B vs "
              f"global={net.comm_bytes_global}B")
        # what each wire codec would ship per step on THIS decomposition
        # (the sparse ID wire wins below the packed crossover firing rate)
        table_b = {w: dist.wire_bytes_per_step(net, "area", w)
                   for w in ("f32", "u8", "packed", "sparse")}
        split = dist.wire_bytes_split(
            "area", args.spike_wire, args.spike_wire_remote,
            n_shards=net.n_shards, row_width=net.row_width,
            n_local=net.n_local, b_pad=net.b_pad)
        run_tag = args.spike_wire + (
            f"+{args.spike_wire_remote}" if args.spike_wire_remote else "")
        print("  wire bytes/step (area): "
              + "  ".join(f"{w}={b}B" for w, b in table_b.items())
              + f"  [running: {run_tag}: intra-host {split['intra']}B + "
              + f"inter-host {split['inter']}B]")
        jstep = jax.jit(step)
        counts = np.zeros(net.n_shards)
        for i in range(args.steps):
            state, bits = jstep(state)
            if i % args.save_every == args.save_every - 1:
                mgr.save(i + 1, state, blocking=False)
            counts += np.asarray(bits).sum(axis=-1)
        mgr.wait()
        overflow = int(np.asarray(state.wire_overflow).sum())
        if overflow:
            print(f"  WARNING: lossy wire saturated {overflow} time(s) - "
                  f"raise the sparse capacity (e.g. sparse:<rate>)")
        total = counts.sum()
        rate = total / (spec.n_neurons * args.steps * models.DT_MS * 1e-3)
    else:
        dec = builder.decompose(spec, 1, method="random")
        g = builder.build_shards(spec, dec)[0].device_arrays()
        cfg = engine.EngineConfig(dt=models.DT_MS)
        state = engine.init_state(g, list(spec.groups), jax.random.key(0))
        step = engine.make_step_fn(g, table, cfg)
        n_spk = 0
        for i in range(args.steps):
            state, bits = step(state)
            n_spk += int(np.asarray(bits).sum())
            if i % args.save_every == args.save_every - 1:
                mgr.save(i + 1, state, blocking=False,
                         metadata={"step": i + 1})
                print(f"  step {i+1}: checkpoint saved")
        mgr.wait()
        rate = n_spk / (spec.n_neurons * args.steps * models.DT_MS * 1e-3)

    print(f"  simulated {args.steps * models.DT_MS:.0f} ms, "
          f"mean rate = {rate:.2f} Hz")
    # restart proof: restore the latest checkpoint
    last = mgr.latest_step()
    if last:
        _, meta = mgr.restore(state)
        print(f"  restored checkpoint @ step {last} ok")
    print("ok")


if __name__ == "__main__":
    main()

"""Diagnostic: list the largest per-device tensors in a compiled dry-run
cell.  Usage: PYTHONPATH=src python scripts/dump_big_tensors.py <arch> <shape>
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
import re
import sys

sys.path.insert(0, "src")
import jax  # noqa: E402

from repro.launch import dryrun  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro import configs  # noqa: E402
from repro.configs.shapes import SHAPES  # noqa: E402
from repro.sharding import rules  # noqa: E402
from repro.utils.hlo import DTYPE_BYTES  # noqa: E402


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    mesh = make_production_mesh(multi_pod="--multi-pod" in sys.argv)
    cfg = configs.get(arch)
    fn, args, donate, out_sh = dryrun.build_cell(cfg, SHAPES[shape], mesh)
    with rules.use_mesh(mesh):
        compiled = jax.jit(fn, donate_argnums=donate,
                           out_shardings=out_sh).lower(*args).compile()
    txt = compiled.as_text()
    sizes = {}
    for m in re.finditer(r"(\w+)\[([\d,]+)\]", txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * DTYPE_BYTES[dt]
        key = f"{dt}[{dims}]"
        if b > 2 ** 27:
            sizes[key] = max(sizes.get(key, 0), b)
    for k, v in sorted(sizes.items(), key=lambda kv: -kv[1])[:20]:
        print(f"{v / 2**30:8.2f} GiB  {k}  x{txt.count(k)}")
    print(compiled.memory_analysis())


if __name__ == "__main__":
    main()

"""Regenerate the data-driven tables of EXPERIMENTS.md from the sweep JSONs.

Writes experiments/tables.md with:
  - per-device peak bytes table (single-pod)
  - the roofline baseline table
  - SNN dry-run table
Run after the final sweeps; paste/compare into EXPERIMENTS.md.
"""

import json
import sys

ARCHS = ["qwen2.5-3b", "phi3-medium-14b", "command-r-plus-104b",
         "internlm2-1.8b", "jamba-v0.1-52b", "rwkv6-3b",
         "deepseek-v3-671b", "qwen3-moe-30b-a3b", "whisper-tiny",
         "internvl2-1b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    out = []
    with open("experiments/dryrun_all.json") as f:
        dr = json.load(f)
    idx = {(r["arch"], r["shape"], r["mesh"]): r for r in dr}

    out.append("## per-device peak GiB (single-pod 16x16)\n")
    out.append("| arch | " + " | ".join(SHAPES) + " |")
    out.append("|---|" + "---|" * len(SHAPES))
    for a in ARCHS:
        row = [a]
        for s in SHAPES:
            r = idx.get((a, s, "16x16"), {})
            if r.get("status") == "ok":
                row.append(f"{r['memory']['peak_bytes']/2**30:.2f}")
            elif r.get("status") == "skipped":
                row.append("skip")
            else:
                row.append(r.get("status", "?"))
        out.append("| " + " | ".join(row) + " |")
    n_ok = sum(r["status"] == "ok" for r in dr)
    n_sk = sum(r["status"] == "skipped" for r in dr)
    n_er = sum(r["status"] == "error" for r in dr)
    out.append(f"\ncells: {n_ok} ok / {n_sk} skipped / {n_er} error "
               f"(both meshes)\n")

    out.append("## roofline baseline (single-pod), FINAL\n")
    with open("experiments/roofline.json") as f:
        rl = json.load(f)
    out.append("| arch | shape | dominant | compute_s | memory_s | "
               "collective_s | useful | roofline |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rl:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                       "| | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['dominant']} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['useful_fraction']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")

    out.append("\n## SNN engine @ production scale\n")
    with open("experiments/dryrun_snn.json") as f:
        sn = json.load(f)
    out.append("| mesh | scale | wire | compact | peak GiB | compute_us | "
               "memory_us | collective_us |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in sn:
        out.append(
            f"| {r['mesh']} | {r['scale']} | {r['wire']} | "
            f"{int(r.get('compact', False))} | {r['peak_gib']:.2f} | "
            f"{r['compute_s']*1e6:.1f} | {r['memory_s']*1e6:.1f} | "
            f"{r['collective_s']*1e6:.2f} |")

    with open("experiments/tables.md", "w") as f:
        f.write("\n".join(out) + "\n")
    print("\n".join(out))


if __name__ == "__main__":
    main()

"""Kernel-path microbenchmarks (CPU-executable proxies).

The Pallas kernels themselves only run in interpret mode here (Python-speed,
not meaningful to time); what we CAN measure on CPU is the XLA formulation
they were derived from - the fused flat sweep and LIF chain - across sizes,
plus the blocked-layout conversion cost.  On TPU the same harness times the
compiled kernels (interpret=False).
"""

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

# allow `python benchmarks/bench_kernels.py` without PYTHONPATH
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import backends, builder, models, snn
from repro.core.autotune import autotune_report
from repro.core.layout import blocked_layout


def bench_sweep_sizes(out, *, quick=False):
    """Sweep-only step time per execution backend (registry dispatch)."""
    sizes = ((0.02, "small"),) if quick else ((0.02, "small"),
                                              (0.08, "medium"))
    for scale, tag in sizes:
        spec, _ = models.hpc_benchmark(scale=scale, stdp=False)
        g = builder.build_shards(spec, builder.decompose(spec, 1))[0] \
            .device_arrays()
        ring = jnp.zeros((spec.max_delay, g.n_mirror), jnp.float32)
        w = g.weight_init

        for name in ("flat", "bucketed", "pallas"):
            backend = backends.get_backend(name)
            layout = backend.prepare(g)

            @jax.jit
            def sweep(ring, t):
                return backend.sweep(layout, w, ring, t)

            r = sweep(ring, jnp.asarray(5, jnp.int32))
            jax.block_until_ready(r)
            n = 20 if quick else 200
            t0 = time.perf_counter()
            for i in range(n):
                r = sweep(ring, jnp.asarray(i % spec.max_delay, jnp.int32))
            jax.block_until_ready(r)
            us = (time.perf_counter() - t0) / n * 1e6
            out(f"kernel_proxy/synaptic_sweep/{name}/{tag}", us,
                f"edges={g.n_edges};edges_per_us={g.n_edges/us:.0f}")


def bench_blocked_layout(out, *, quick=False):
    """Build-time flat -> post-block ELL conversion (vectorized scatter)."""
    sizes = ((0.05, "small"),) if quick else ((0.05, "small"),
                                              (0.2, "medium"))
    for scale, tag in sizes:
        spec, _ = models.hpc_benchmark(scale=scale, stdp=False)
        g = builder.build_shards(spec, builder.decompose(spec, 1),
                                 with_blocked=False)[0]
        blocked_layout(g)  # warm numpy caches
        n = 3 if quick else 20
        t0 = time.perf_counter()
        for _ in range(n):
            bg = blocked_layout(g)
        us = (time.perf_counter() - t0) / n * 1e6
        out(f"kernel_proxy/blocked_layout/{tag}", us,
            f"edges={g.n_edges};nb={bg.nb};eb={bg.eb}")


def bench_autotune(out, *, quick=False):
    """Chosen (PB, EB) vs the fixed defaults per shard-degree distribution
    (single-shard and a stacked multi-shard set), with the padded-slot and
    VMEM model terms that drove the choice."""
    sizes = ((0.02, 1, "small-1dev"),) if quick else (
        (0.02, 1, "small-1dev"), (0.1, 1, "medium-1dev"),
        (0.05, 4, "small-4dev"))
    for scale, n_dev, tag in sizes:
        spec, _ = models.hpc_benchmark(scale=scale)
        shards = builder.build_shards(spec, builder.decompose(spec, n_dev),
                                      with_blocked=False)
        rep = autotune_report(shards)
        out(f"kernel_proxy/autotune/{tag}", rep["padded_slots"],
            f"pb={rep['pb']};eb={rep['eb']};"
            f"default=({rep['default_pb']},{rep['default_eb']});"
            f"slots_vs_default={rep['slots_vs_default']};"
            f"pad_ratio={rep['pad_ratio']};"
            f"default_pad_ratio={rep['default_pad_ratio']};"
            f"vmem_kib={rep['vmem_kib']};feasible={rep['feasible']}")


def bench_lif_chain(out, *, quick=False):
    for n in ((4096,) if quick else (4096, 65536)):
        gs = [snn.LIFParams()]
        table = snn.make_param_table(gs, dt=0.1)
        state = snn.init_state(n, np.zeros(n, np.int32), gs)
        zeros = jnp.zeros(n)

        @jax.jit
        def step(s):
            return snn.lif_step(s, table, zeros, zeros)

        s = step(state)
        jax.block_until_ready(s.v_m)
        reps = 20 if quick else 200
        t0 = time.perf_counter()
        for _ in range(reps):
            s = step(s)
        jax.block_until_ready(s.v_m)
        us = (time.perf_counter() - t0) / reps * 1e6
        out(f"kernel_proxy/lif_step/n{n}", us,
            f"neurons_per_us={n/us:.0f}")


def main(out, *, quick: bool = False, autotune: bool = False):
    if autotune:
        # (PB, EB) table only - chosen vs the fixed defaults
        bench_autotune(out, quick=quick)
        return
    bench_sweep_sizes(out, quick=quick)
    bench_lif_chain(out, quick=quick)
    bench_blocked_layout(out, quick=quick)
    bench_autotune(out, quick=quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="kernel-path microbenchmarks (CPU-executable proxies)")
    ap.add_argument("--quick", action="store_true",
                    help="tiny config: smallest sizes, few reps (CI smoke)")
    ap.add_argument("--autotune", action="store_true",
                    help="print only the chosen (PB, EB) table vs the "
                         "fixed defaults (repro.core.autotune)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(lambda name, us, derived="": print(f"{name},{us:.2f},{derived}",
                                            flush=True),
         quick=args.quick, autotune=args.autotune)

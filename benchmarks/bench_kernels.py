"""Kernel-path microbenchmarks (CPU-executable proxies).

The Pallas kernels themselves only run in interpret mode here (Python-speed,
not meaningful to time); what we CAN measure on CPU is the XLA formulation
they were derived from - the fused flat sweep and LIF chain - across sizes,
plus the blocked-layout conversion cost.  On TPU the same harness times the
compiled kernels (interpret=False).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import builder, engine, models, snn


def bench_sweep_sizes(out):
    for scale, tag in ((0.02, "small"), (0.08, "medium")):
        spec, _ = models.hpc_benchmark(scale=scale, stdp=False)
        g = builder.build_shards(spec, builder.decompose(spec, 1))[0] \
            .device_arrays()
        ring = jnp.zeros((spec.max_delay, g.n_mirror), jnp.float32)
        w = g.weight_init

        @jax.jit
        def sweep(ring, t):
            return engine.synaptic_sweep(g, w, ring, t, mode="flat")

        r = sweep(ring, jnp.asarray(5, jnp.int32))
        jax.block_until_ready(r)
        n = 200
        t0 = time.perf_counter()
        for i in range(n):
            r = sweep(ring, jnp.asarray(i % spec.max_delay, jnp.int32))
        jax.block_until_ready(r)
        us = (time.perf_counter() - t0) / n * 1e6
        out(f"kernel_proxy/synaptic_sweep/{tag}", us,
            f"edges={g.n_edges};edges_per_us={g.n_edges/us:.0f}")


def bench_lif_chain(out):
    for n in (4096, 65536):
        gs = [snn.LIFParams()]
        table = snn.make_param_table(gs, dt=0.1)
        state = snn.init_state(n, np.zeros(n, np.int32), gs)
        zeros = jnp.zeros(n)

        @jax.jit
        def step(s):
            return snn.lif_step(s, table, zeros, zeros)

        s = step(state)
        jax.block_until_ready(s.v_m)
        reps = 200
        t0 = time.perf_counter()
        for _ in range(reps):
            s = step(s)
        jax.block_until_ready(s.v_m)
        us = (time.perf_counter() - t0) / reps * 1e6
        out(f"kernel_proxy/lif_step/n{n}", us,
            f"neurons_per_us={n/us:.0f}")


def main(out):
    bench_sweep_sizes(out)
    bench_lif_chain(out)

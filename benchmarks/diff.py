"""Perf-trajectory guard: diff a fresh BENCH run against the committed
baseline (``benchmarks/run.py --json`` output).

Seven independent checks, ordered machine-independent first:

1. **Structure** - the fresh run must produce exactly the committed
   record set (a silently dropped backend/wire/phase leg fails CI even
   if everything that still runs got faster).
2. **Exact invariants** - byte counts, capacities, geometry and overflow
   fields are machine-independent and must match the baseline exactly.
3. **Gate win** - from the FRESH run alone: at the sparsest activity
   regime the gated ``sweep_plus_stdp`` must beat dense pallas by the
   required factor (the pallas:sparse acceptance bar, immune to runner
   speed).
4. **Session win** - from the FRESH run alone: the batched vmapped slot
   batch must beat N sequential one-shot runs in aggregate steps/sec
   (the multi-tenant serving claim, DESIGN.md §16).
5. **Build RSS** - from the FRESH run alone: the procedural O(owned
   rows) build must peak strictly below the materialize-then-route
   pipeline at the largest scale both modes ran (the DESIGN.md §14
   memory claim, immune to absolute RSS baselines).
6. **Remat win** - from the FRESH run alone: the checkpointed rollout
   gradient's compiled peak temp memory must stay strictly below the
   naive scan's at T=200 (the DESIGN.md §17 remat policy; byte counts
   are jax-version-dependent, so only the ordering is guarded).
7. **Timing drift** - fresh/baseline timing ratios, normalized by the
   run's median ratio (cancels absolute machine speed), must stay inside
   a wide band; catches one phase regressing relative to the rest.

    python benchmarks/diff.py /tmp/BENCH_fresh.json \
        --baseline BENCH_quick.json
"""

import argparse
import json
import sys

# machine-independent fields that must match the baseline bit-for-bit
# (gate_tune's overflow_rate/occupancy/peak_active come from a fixed-seed
# simulation, deterministic like snn_gate's n_active/overflow; the
# snn_sessions geometry fields pin the benchmark shape itself)
EXACT_FIELDS = ("wire_bytes_step", "wire_bytes_intra", "wire_bytes_inter",
                "comm_bytes_step", "remote_mirrors", "capacity", "nb",
                "eb", "pb", "edges", "active_fraction", "overflow",
                "n_active", "ckpt_bytes", "ckpt_leaves", "overflow_rate",
                "occupancy", "peak_active", "n_sessions", "n_steps",
                "warmup", "checkpoint_every")


def _records(path):
    with open(path) as f:
        payload = json.load(f)
    recs = payload["records"] if isinstance(payload, dict) else payload
    return {r["name"]: r for r in recs}


def _median(xs):
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def check_structure(fresh, base, errors):
    missing = sorted(set(base) - set(fresh))
    extra = sorted(set(fresh) - set(base))
    if missing:
        errors.append(f"records missing from fresh run: {missing}")
    if extra:
        errors.append(f"records not in baseline (re-commit it): {extra}")


def check_exact(fresh, base, errors):
    for name in sorted(set(fresh) & set(base)):
        for field in EXACT_FIELDS:
            if field in base[name] and field in fresh[name]:
                b, f = base[name][field], fresh[name][field]
                if b != f:
                    errors.append(
                        f"{name}: {field} changed {b} -> {f} (exact "
                        f"invariant; re-commit the baseline if intended)")


def check_gate_win(fresh, errors, *, factor):
    gate = [r for r in fresh.values()
            if r["name"].startswith("snn_gate/")
            and r.get("phase") == "sweep_plus_stdp"]
    if not gate:
        errors.append("no snn_gate sweep_plus_stdp records in fresh run")
        return
    sparsest = min(r["active_fraction"] for r in gate)
    pair = {r["name"].split("/")[1]: r["us_per_call"]
            for r in gate if r["active_fraction"] == sparsest}
    if not {"dense", "sparse"} <= set(pair):
        errors.append(f"gate records incomplete at act={sparsest}: {pair}")
        return
    bar = factor * pair["dense"]
    if pair["sparse"] > bar:
        errors.append(
            f"gate win lost at act={sparsest}: sparse sweep_plus_stdp "
            f"{pair['sparse']:.1f}us > {factor} x dense "
            f"{pair['dense']:.1f}us")
    else:
        print(f"gate win at act={sparsest}: sparse "
              f"{pair['sparse']:.1f}us vs dense {pair['dense']:.1f}us "
              f"({pair['dense'] / max(pair['sparse'], 1e-9):.2f}x)")


def check_session_win(fresh, errors, *, factor):
    """Multi-tenant serving claim, fresh run only: the batched vmapped
    slot batch must beat N sequential one-shot runs by ``factor`` in
    aggregate steps/sec (DESIGN.md §16; the committed number is the
    ISSUE 9 >= 4x acceptance bar, the CI floor is looser to absorb
    runner-speed effects on subprocess startup)."""
    batched = [r for r in fresh.values()
               if r["name"].startswith("snn_sessions/batched/")]
    if not batched:
        errors.append("no snn_sessions/batched records in fresh run")
        return
    for r in batched:
        win = r.get("speedup_vs_sequential")
        if win is None:
            errors.append(f"{r['name']}: speedup_vs_sequential missing")
        elif win < factor:
            errors.append(
                f"{r['name']}: batched sessions only {win}x the "
                f"sequential one-shot baseline (floor {factor}x)")
        else:
            print(f"session win at {r['name']}: {win}x sequential "
                  f"(compute-only {r.get('speedup_vs_sequential_compute')}x)")


def check_build_rss(fresh, errors):
    """Procedural < materialized build peak RSS, fresh run only."""
    by = {}
    for r in fresh.values():
        if r["name"].startswith("snn_build/"):
            mode = r["name"].split("/")[1]
            by.setdefault(r["scale"], {})[mode] = r["peak_rss_mb"]
    common = [s for s, m in by.items()
              if {"materialized", "procedural"} <= set(m)]
    if not common:
        errors.append("no scale with both snn_build modes in fresh run: "
                      f"{sorted(by)}")
        return
    s = max(common)
    mat, proc = by[s]["materialized"], by[s]["procedural"]
    if proc >= mat:
        errors.append(
            f"procedural build peak RSS {proc}MB is not below the "
            f"materialized pipeline's {mat}MB at scale {s} (the O(owned "
            f"rows) memory claim)")
    else:
        print(f"build RSS at scale {s}: procedural {proc}MB vs "
              f"materialized {mat}MB ({mat / max(proc, 1e-9):.2f}x)")


def check_remat_win(fresh, errors):
    """Checkpointed-rollout memory claim, fresh run only: the chunked
    ``jax.checkpoint`` gradient's compiled peak TEMP bytes must stay
    strictly below the naive scan's at T=200 (DESIGN.md §17 - the remat
    policy ``repro.diff`` trains under).  Absolute byte counts are
    jax-version-dependent, so only the ordering is guarded."""
    mem = {r["name"].split("/")[-1]: r for r in fresh.values()
           if r["name"].startswith("snn_surrogate/rollout_mem/")}
    if not mem:
        errors.append("no snn_surrogate/rollout_mem records in fresh run")
        return
    naive = mem.get("naive")
    ckpts = {k: r for k, r in mem.items() if k != "naive"}
    if naive is None or not ckpts:
        errors.append(f"rollout_mem records incomplete: {sorted(mem)}")
        return
    for k, r in sorted(ckpts.items()):
        if r["temp_bytes"] >= naive["temp_bytes"]:
            errors.append(
                f"remat win lost at T={r['n_steps']}: {k} grad peak "
                f"temp {r['temp_bytes']}B >= naive "
                f"{naive['temp_bytes']}B")
        else:
            print(f"remat win at T={r['n_steps']}: {k} grad peak temp "
                  f"{r['temp_bytes']}B vs naive {naive['temp_bytes']}B "
                  f"({naive['temp_bytes'] / max(r['temp_bytes'], 1):.2f}x)")


def check_drift(fresh, base, errors, *, band):
    shared = sorted(set(fresh) & set(base))
    ratios = {}
    for name in shared:
        b, f = base[name]["us_per_call"], fresh[name]["us_per_call"]
        if b > 0 and f > 0:
            ratios[name] = f / b
    if not ratios:
        return
    med = _median(list(ratios.values()))
    print(f"median fresh/baseline timing ratio: {med:.2f} "
          f"({len(ratios)} records)")
    for name, r in ratios.items():
        rel = r / med
        if rel > band or rel < 1.0 / band:
            errors.append(
                f"{name}: timing drifted {rel:.2f}x relative to the "
                f"run median (band {band}x): fresh "
                f"{fresh[name]['us_per_call']}us vs baseline "
                f"{base[name]['us_per_call']}us")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated BENCH json")
    ap.add_argument("--baseline", default="BENCH_quick.json",
                    help="committed baseline to diff against")
    ap.add_argument("--drift", type=float, default=4.0,
                    help="allowed median-normalized timing ratio band")
    ap.add_argument("--gate-factor", type=float, default=0.9,
                    help="sparse must beat dense sweep_plus_stdp by this "
                         "factor at the sparsest activity regime")
    ap.add_argument("--session-factor", type=float, default=2.0,
                    help="batched sessions must beat the sequential "
                         "one-shot baseline by this aggregate steps/sec "
                         "factor (committed acceptance number is 4x)")
    args = ap.parse_args(argv)

    fresh, base = _records(args.fresh), _records(args.baseline)
    errors = []
    check_structure(fresh, base, errors)
    check_exact(fresh, base, errors)
    check_gate_win(fresh, errors, factor=args.gate_factor)
    check_session_win(fresh, errors, factor=args.session_factor)
    check_build_rss(fresh, errors)
    check_remat_win(fresh, errors)
    check_drift(fresh, base, errors, band=args.drift)

    if errors:
        print(f"\nFAIL: {len(errors)} perf-trajectory violation(s)")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"\nOK: {len(set(fresh) & set(base))} records match the "
          f"committed trajectory")
    return 0


if __name__ == "__main__":
    sys.exit(main())

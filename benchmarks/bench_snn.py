"""Fig. 18 analogue: memory + step-time scaling of the SNN engine.

The paper's headline figure compares CORTEX vs NEST on the marmoset
benchmark across normalized problem sizes (memory per node, wall time).
On this CPU container we reproduce the *shape* of that comparison:

* problem-size scaling of step wall-time and per-shard memory for the
  CORTEX engine across every execution backend (``--backend
  {flat,bucketed,pallas}`` restricts the axis; pallas runs in interpret
  mode off-TPU, so its CPU numbers measure the emulated kernel, not the
  TPU lowering);
* Area-Processes Mapping vs Random Equivalent Mapping: remote-mirror
  memory and per-step spike-exchange bytes (the Fig. 8/9/10 quantities,
  computed exactly from the built shards - these are the terms that
  dominate at Fugaku scale).
"""

import argparse
import os
import sys
import time

import jax
import numpy as np

# allow `python benchmarks/bench_snn.py --backend ...` without PYTHONPATH
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import builder, engine, models, snn
from repro.core.backends import available_backends
from repro.core.distributed import mesh_decompose, prepare_stacked

DEFAULT_BACKENDS = available_backends()


def _bytes_of_shard(g) -> int:
    tot = 0
    for f in ("pre_idx", "post_idx", "delay", "channel", "weight_init"):
        tot += np.asarray(getattr(g, f)).nbytes
    tot += np.asarray(g.mirror_src_shard).nbytes * 2
    return tot


def bench_step_scaling(out, backends=DEFAULT_BACKENDS):
    for scale in (0.02, 0.05, 0.1):
        spec, stdp = models.hpc_benchmark(scale=scale, stdp=True)
        dec = builder.decompose(spec, 1)
        g = builder.build_shards(spec, dec)[0].device_arrays()
        table = snn.make_param_table(list(spec.groups), dt=0.1)
        for sweep in backends:
            cfg = engine.EngineConfig(dt=0.1, stdp=stdp, sweep=sweep)
            st = engine.init_state(g, list(spec.groups), jax.random.key(0))
            step = engine.make_step_fn(g, table, cfg)
            st, _ = step(st)  # compile+warm
            n = 100
            t0 = time.perf_counter()
            for _ in range(n):
                st, _ = step(st)
            jax.block_until_ready(st.v_m if hasattr(st, "v_m")
                                  else st.neurons.v_m)
            us = (time.perf_counter() - t0) / n * 1e6
            out(f"snn_step/{sweep}/scale{scale}", us,
                f"edges={g.n_edges}")


def bench_mapping_comparison(out):
    """Area vs Random mapping: mirrors + spike traffic (paper Fig. 8-10)."""
    for scale in (0.004, 0.008):
        spec = models.marmoset(scale=scale, n_areas=4)
        for method, tag in (("area", "cortex_area"),
                            ("random", "random_equiv")):
            dec = mesh_decompose(spec, n_rows=4, row_width=2, method=method)
            net = prepare_stacked(spec, dec, 4, 2)
            shards = builder.build_shards(spec, dec)
            mem = sum(_bytes_of_shard(g) for g in shards) / len(shards)
            remote = sum(int(g.n_mirror) - int(dec.parts[i].size)
                         for i, g in enumerate(shards))
            comm = (net.comm_bytes_area if method == "area"
                    else net.comm_bytes_global)
            out(f"snn_map/{tag}/scale{scale}", mem,
                f"remote_mirrors={remote};comm_bytes_step={comm}")


def main(out, backend: str | None = None):
    bench_step_scaling(out, (backend,) if backend else DEFAULT_BACKENDS)
    bench_mapping_comparison(out)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="SNN engine scaling benchmark with a backend axis")
    ap.add_argument("--backend", default=None,
                    choices=sorted(available_backends()),
                    help="restrict the step benchmark to one execution "
                         "backend (default: flat, bucketed and pallas)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(lambda name, us, derived="": print(f"{name},{us:.2f},{derived}",
                                            flush=True),
         args.backend)

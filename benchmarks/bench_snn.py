"""Fig. 18 analogue: memory + step-time scaling of the SNN engine.

The paper's headline figure compares CORTEX vs NEST on the marmoset
benchmark across normalized problem sizes (memory per node, wall time).
On this CPU container we reproduce the *shape* of that comparison:

* problem-size scaling of step wall-time and per-shard memory for the
  CORTEX engine across every execution backend (``--backend
  {flat,bucketed,pallas}`` restricts the axis; pallas runs in interpret
  mode off-TPU, so its CPU numbers measure the emulated kernel, not the
  TPU lowering);
* the distributed step across every spike-wire codec and comm mode
  (``--spike-wire`` / ``--comm-mode`` restrict the axes;
  ``--spike-wire-remote`` puts a different codec on the cross-row
  boundary tier) - the end-to-end cost of the SpikeWire
  encode/collective/decode path, with the codec's own wire bytes/step
  split intra/inter-host next to the timing;
* the same step across N REAL local processes (``--processes``, via the
  ``repro.launch.multihost`` launcher - gloo collectives on a
  host-aligned mesh), the multi-host scaling axis;
* Area-Processes Mapping vs Random Equivalent Mapping: remote-mirror
  memory and per-step spike-exchange bytes (the Fig. 8/9/10 quantities,
  computed exactly from the built shards - these are the terms that
  dominate at Fugaku scale).

Results also land as JSON (``--json``, default experiments/bench_snn.json)
so the wire bytes/step ride along with the timings.  ``--quick`` shrinks
every axis to a CI-smoke-sized config.  The wire benchmark shards over
however many devices exist (set
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for a real mesh).
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

# allow `python benchmarks/bench_snn.py --backend ...` without PYTHONPATH
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import backends as backends_mod
from repro.core import builder, engine, models, snn, stdp as stdp_mod
from repro.core import neuron_models as neuron_models_mod
from repro.core.backends import available_backends
from repro.core.distributed import (DistributedConfig, init_stacked_state,
                                    make_distributed_step, mesh_decompose,
                                    prepare_stacked, wire_bytes_per_step,
                                    wire_bytes_split)

DEFAULT_BACKENDS = available_backends()
DEFAULT_WIRES = ("f32", "u8", "packed", "sparse")
DEFAULT_COMM_MODES = ("area", "global")


def _bytes_of_shard(g) -> int:
    tot = 0
    for f in ("pre_idx", "post_idx", "delay", "channel", "weight_init"):
        tot += np.asarray(getattr(g, f)).nbytes
    tot += np.asarray(g.mirror_src_shard).nbytes * 2
    return tot


def _scenario_net(scale, *, model="lif", scenario=None):
    """(spec, stdp, tag) for the step-scaling axes: the hpc verification
    net by default, a named scenario-zoo entry, or the cross-model demo
    network for a NeuronModel (ISSUE: the --model / --scenario axes)."""
    if scenario:
        spec, stdp = models.get_scenario(scenario, scale=scale)
        return spec, stdp, scenario
    if model != "lif":
        spec, stdp = models.model_demo(model, scale=scale,
                                       stdp=(model != "poisson"))
        return spec, stdp, f"demo-{model}"
    spec, stdp = models.hpc_benchmark(scale=scale, stdp=True)
    return spec, stdp, "hpc_benchmark"


def bench_step_scaling(out, backends=DEFAULT_BACKENDS, *, quick=False,
                       model="lif", scenario=None):
    scales = (0.02,) if quick else (0.02, 0.05, 0.1)
    reps = 20 if quick else 100
    for scale in scales:
        spec, stdp, tag = _scenario_net(scale, model=model,
                                        scenario=scenario)
        nmodel = neuron_models_mod.get_model(spec.neuron_model)
        dec = builder.decompose(spec, 1)
        g = builder.build_shards(spec, dec)[0].device_arrays()
        table = nmodel.make_param_table(list(spec.groups), dt=0.1)
        for sweep in backends:
            cfg = engine.EngineConfig(dt=0.1, stdp=stdp, sweep=sweep,
                                      neuron_model=spec.neuron_model)
            # native-layout weights: the measured loop is the resident hot
            # path, not the flat-state compatibility conversion
            st = engine.init_state(g, list(spec.groups), jax.random.key(0),
                                   sweep=sweep,
                                   neuron_model=spec.neuron_model)
            step = engine.make_step_fn(g, table, cfg)
            st, _ = step(st)  # compile+warm
            t0 = time.perf_counter()
            for _ in range(reps):
                st, _ = step(st)
            jax.block_until_ready(st.v_m if hasattr(st, "v_m")
                                  else st.neurons.v_m)
            us = (time.perf_counter() - t0) / reps * 1e6
            out(f"snn_step/{sweep}/{tag}/scale{scale}", us,
                dict(edges=g.n_edges, model=spec.neuron_model,
                     scenario=tag))


def _time(fn, args, reps):
    r = fn(*args)
    jax.block_until_ready(jax.tree.leaves(r)[0])
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(jax.tree.leaves(r)[0])
    return (time.perf_counter() - t0) / reps * 1e6


def bench_profile(out, backends=DEFAULT_BACKENDS, *, quick=False,
                  model="lif", scenario=None):
    """Per-phase hot-path breakdown: sweep / neuron_update / stdp per
    execution backend on one shard (weights in the backend's NATIVE layout,
    as the engine carries them - the loop pays no ``edge_perm``
    conversion), plus the spike-exchange phase through the real shard_map
    collective path.  The ``sweep_plus_stdp`` record is the ISSUE's
    acceptance metric for the fused blocked hot path.  ``model`` /
    ``scenario`` swap the network and the neuron_update dynamics (the
    NeuronModel registry axis); every record carries the model name."""
    scale = 0.02 if quick else 0.1
    reps = 5 if quick else 30
    spec, stdp_params, tag = _scenario_net(scale, model=model,
                                           scenario=scenario)
    if stdp_params is None:
        stdp_params = models.HPC_STDP   # profile the plasticity phase too
    nmodel = neuron_models_mod.get_model(spec.neuron_model)
    g = builder.build_shards(spec, builder.decompose(spec, 1))[0] \
        .device_arrays()
    table = jnp.asarray(nmodel.make_param_table(list(spec.groups), dt=0.1))
    rng = np.random.default_rng(0)
    ring = jnp.asarray((rng.uniform(size=(spec.max_delay, g.n_mirror))
                        < 0.02).astype(np.float32))
    spk = jnp.asarray((rng.uniform(size=g.n_local) < 0.05)
                      .astype(np.float32))
    neurons = nmodel.init_state(g.n_local, np.asarray(g.group_id),
                                list(spec.groups))
    mkey = jax.random.key(0) if nmodel.stochastic else None
    t0j = jnp.asarray(0, jnp.int32)
    traces = stdp_mod.init_traces(g.n_mirror, g.n_local, jnp.float32)
    iex = jnp.asarray(rng.uniform(0, 50, g.n_local).astype(np.float32))
    iin = jnp.asarray(rng.uniform(-50, 0, g.n_local).astype(np.float32))
    for name in backends:
        backend = backends_mod.get_backend(name)
        layout = backend.prepare(g)
        w = backend.to_native_weights(layout, g.weight_init)
        meta = dict(edges=g.n_edges, scale=scale, phase=None,
                    model=spec.neuron_model)

        sweep = jax.jit(lambda w, ring, t: backend.sweep(layout, w, ring, t))
        t5 = jnp.asarray(5, jnp.int32)
        sweep_us = _time(sweep, (w, ring, t5), reps)
        out(f"snn_profile/{name}/sweep", sweep_us,
            dict(meta, phase="sweep"))

        nup = jax.jit(lambda n, iex, iin: backend.neuron_update(
            layout, n, table, iex, iin, model=nmodel, key=mkey, t=t0j))
        out(f"snn_profile/{name}/neuron_update",
            _time(nup, (neurons, iex, iin), reps),
            dict(meta, phase="neuron_update"))

        _, _, arrived = sweep(w, ring, t5)
        supd = jax.jit(lambda w, a, s: backend.stdp_update(
            layout, w, a, s, traces, stdp_params))
        stdp_us = _time(supd, (w, arrived, spk), reps)
        out(f"snn_profile/{name}/stdp", stdp_us,
            dict(meta, phase="stdp"))
        out(f"snn_profile/{name}/sweep_plus_stdp", sweep_us + stdp_us,
            dict(meta, phase="sweep_plus_stdp"))
    _bench_profile_exchange(out, reps)


def _bench_profile_exchange(out, reps):
    """The exchange phase, isolated: encode -> collective(s) -> decode of
    the two-level spike exchange on whatever host mesh exists."""
    from jax.sharding import PartitionSpec as P
    from repro.core.distributed import _exchange
    from repro.utils.jax_compat import shard_map

    n_dev = jax.device_count()
    width = 2 if n_dev % 2 == 0 else 1
    rows = n_dev // width
    mesh = jax.make_mesh((rows, width), ("data", "model"))
    spec = models.marmoset(scale=0.004, n_areas=4)
    dec = mesh_decompose(spec, rows, width)
    net = prepare_stacked(spec, dec, rows, width, with_blocked=False)
    consts = dict(
        boundary_slots=jnp.asarray(net.boundary_slots),
        mirror_is_intra=jnp.asarray(net.mirror_is_intra),
        mirror_row_gather=jnp.asarray(net.mirror_row_gather),
        mirror_remote_gather=jnp.asarray(net.mirror_remote_gather),
        mirror_src_flat=jnp.asarray(net.mirror_src_flat),
        mirror_src_idx=jnp.asarray(net.graph["mirror_src_idx"]),
    )
    rng = np.random.default_rng(1)
    bits = jnp.asarray((rng.uniform(size=(net.n_shards, net.n_local))
                        < 0.01).astype(np.float32))
    for mode in ("area", "global"):
        cfg = DistributedConfig(engine=engine.EngineConfig(dt=0.1),
                                comm_mode=mode, spike_wire="packed")
        wire = cfg.wire

        def local(b, g):
            mirror, _ = _exchange(b[0], {k: v[0] for k, v in g.items()},
                                  cfg, wire)
            return mirror[None]

        spec_p = P(("data", "model"))
        ex = jax.jit(shard_map(local, mesh=mesh,
                               in_specs=(spec_p, spec_p),
                               out_specs=spec_p))
        out(f"snn_profile/exchange/{mode}-packed",
            _time(ex, (bits, consts), reps),
            dict(phase="exchange", comm_mode=mode, mesh=f"{rows}x{width}",
                 wire_bytes_step=wire_bytes_per_step(net, mode, "packed")))


def _area_localized_layout(nb, pb, eb, *, max_delay=8, pres_per_block=32,
                           seed=0):
    """Synthetic area-localized blocked layout: block b's edges draw ONLY
    from its own mirror slice (the Area-Processes Mapping premise - a post
    block's indegree sub-graph is its own area's projection).  This is the
    geometry where activity gating has leverage: a quiet area leaves its
    blocks with zero arrivals.  Random dense connectivity (the hpc net)
    de-gates at any realistic rate - every block sees every spike."""
    from repro.core.layout import BlockedGraph
    rng = np.random.default_rng(seed)
    n_local = nb * pb - pb // 2              # ragged tail block, like prod
    n_mirror = nb * pres_per_block
    pre = np.zeros((nb, eb), np.int32)
    post_rel = np.zeros((nb, eb), np.int32)
    delay = np.zeros((nb, eb), np.int32)
    channel = np.zeros((nb, eb), np.int32)
    plastic = np.zeros((nb, eb), bool)
    weight = np.zeros((nb, eb), np.float32)
    for b in range(nb):
        ne = eb - 16
        pre[b, :ne] = rng.integers(b * pres_per_block,
                                   (b + 1) * pres_per_block, ne)
        hi = pb if (b + 1) * pb <= n_local else n_local - b * pb
        post_rel[b, :ne] = rng.integers(0, hi, ne)
        delay[b, :ne] = rng.integers(1, max_delay + 1, ne)
        channel[b, :ne] = rng.integers(0, 2, ne)
        plastic[b, :ne] = rng.uniform(size=ne) < 0.7
        weight[b, :ne] = rng.uniform(1.0, 50.0, ne)  # inside [w_min, w_max]
    bg = BlockedGraph(nb=nb, eb=eb, pb=pb, n_local=n_local,
                      pre_idx=jnp.asarray(pre),
                      post_rel=jnp.asarray(post_rel),
                      delay=jnp.asarray(delay), channel=jnp.asarray(channel),
                      plastic=jnp.asarray(plastic),
                      edge_perm=jnp.asarray(
                          np.arange(nb * eb, dtype=np.int32).reshape(nb, eb)),
                      weight=None)
    flat = lambda a: jnp.asarray(a.reshape(-1))
    layout = backends_mod.EdgeLayout(
        n_local=n_local, n_mirror=n_mirror, max_delay=max_delay,
        pre_idx=flat(pre), post_idx=flat(post_rel), delay=flat(delay),
        channel=flat(channel), plastic=flat(plastic), blocked=bg)
    return layout, jnp.asarray(weight.reshape(-1))


def bench_gate_activity(out, *, quick=False):
    """The pallas:sparse acceptance axis: dense vs activity-gated
    sweep+stdp across active-area fractions on the area-localized layout.

    ``active_fraction`` is the fraction of post blocks whose pre-area is
    firing this step (within an active area neurons fire at a biological
    few-percent-per-step rate; quiet areas are exactly silent).  The gate
    is provisioned per regime the way ``dryrun_snn`` recommends: capacity
    sized to ~1.5x the expected active blocks, floor 2.  At fraction 1.0
    capacity clamps to nb and the backend degenerates to the plain dense
    reduce - the graceful-degradation end of the curve; the prepass cost
    it still pays is the gate's overhead ceiling."""
    if quick:
        nb, pb, eb, reps = 12, 128, 512, 5
        fracs = (1.0, 0.0625)
    else:
        nb, pb, eb, reps = 64, 256, 2048, 10
        fracs = (1.0, 0.25, 0.0625, 0.03125)
    layout, w = _area_localized_layout(nb, pb, eb)
    bg = layout.blocked
    dense = backends_mod.get_backend("pallas")
    params = models.HPC_STDP
    rng = np.random.default_rng(3)
    D, M = layout.max_delay, layout.n_mirror
    traces = stdp_mod.init_traces(M, layout.n_local, jnp.float32)
    t5 = jnp.asarray(5, jnp.int32)
    ppb = M // nb
    for frac in fracs:
        # activity localized to ceil(frac*nb) areas; ~3%/step inside them
        n_act = max(int(np.ceil(frac * nb)), 1)
        act_blocks = rng.choice(nb, size=n_act, replace=False)
        pre_mask = np.zeros(M, np.float32)
        for b in act_blocks:
            pre_mask[b * ppb:(b + 1) * ppb] = 1.0
        ring = jnp.asarray((rng.uniform(size=(D, M)) < 0.03)
                           .astype(np.float32) * pre_mask)
        post_mask = np.zeros(layout.n_local, np.float32)
        for b in act_blocks:
            post_mask[b * pb:min((b + 1) * pb, layout.n_local)] = 1.0
        spk = jnp.asarray((rng.uniform(size=layout.n_local) < 0.05)
                          .astype(np.float32) * post_mask)
        # provision the gate for the regime: capacity ~ 1.5x expected
        # active blocks (solve the gate_capacity policy backwards)
        cap_target = min(max(int(np.ceil(1.5 * frac * nb)), 2), nb)
        k = (bg.nb * bg.eb) / nb
        rate = float(1.0 - (1.0 - min(cap_target / nb, 1.0 - 1e-9))
                     ** (1.0 / k))
        sp = backends_mod.SparsePallasBackend(gate_rate=max(rate, 1e-9),
                                              min_capacity=2)
        cap = sp.gate_capacity(layout)
        for name, be in (("dense", dense), ("sparse", sp)):
            meta = dict(nb=nb, eb=eb, pb=pb, active_fraction=frac,
                        capacity=(cap if name == "sparse" else nb),
                        phase=None)
            if name == "sparse":
                sweep = jax.jit(lambda w, r, t, b=be: b.sweep_with_stats(
                    layout, w, r, t))
                *_, ovf = sweep(w, ring, t5)
                meta["overflow"] = int(ovf)
                _, n_active, _ = be.gate_stats(layout, ring, t5)
                meta["n_active"] = int(n_active)
            else:
                sweep = jax.jit(lambda w, r, t, b=be: b.sweep(
                    layout, w, r, t))
            sweep_us = _time(sweep, (w, ring, t5), reps)
            out(f"snn_gate/{name}/act{frac:g}/sweep", sweep_us,
                dict(meta, phase="sweep"))
            arrived = sweep(w, ring, t5)[2]
            supd = jax.jit(lambda w, a, s, b=be: b.stdp_update(
                layout, w, a, s, traces, params))
            stdp_us = _time(supd, (w, arrived, spk), reps)
            out(f"snn_gate/{name}/act{frac:g}/stdp", stdp_us,
                dict(meta, phase="stdp"))
            out(f"snn_gate/{name}/act{frac:g}/sweep_plus_stdp",
                sweep_us + stdp_us, dict(meta, phase="sweep_plus_stdp"))


def bench_wire_exchange(out, wires=DEFAULT_WIRES,
                        comm_modes=DEFAULT_COMM_MODES, *,
                        remote_wire=None, quick=False, model="lif",
                        scenario=None, backend=None):
    """Distributed step time per (spike-wire codec x comm mode).

    Uses whatever devices this process has (1 is fine: the encode/decode
    work and the payload shapes are identical; only the collective hop is
    degenerate), so the codecs are measured end-to-end through the real
    shard_map step.  ``remote_wire`` puts a different codec on the
    cross-row boundary tier (the inter-host hop under a host-aligned
    mesh); the JSON records split the wire bytes intra/inter either way.
    ``scenario``/``model`` swap the network (default: the multi-area
    marmoset case) - e.g. ``--scenario brunel --backend pallas
    --spike-wire sparse`` runs the zoo end-to-end through the sharded
    step; ``backend`` selects the execution backend (default flat).
    """
    n_dev = jax.device_count()
    width = 2 if n_dev % 2 == 0 else 1
    rows = n_dev // width
    mesh = jax.make_mesh((rows, width), ("data", "model"))
    if scenario or model != "lif":
        spec, _, tag = _scenario_net(0.02, model=model, scenario=scenario)
    else:
        spec, tag = models.marmoset(scale=0.004, n_areas=4), "marmoset"
    sweep = backend or "flat"
    needs_blocked = backends_mod.get_backend(sweep).needs_blocked
    dec = mesh_decompose(spec, rows, width)
    net = prepare_stacked(spec, dec, rows, width,
                          with_blocked=needs_blocked)
    reps = 10 if quick else 50
    for mode in comm_modes:
        for wire in wires:
            cfg = DistributedConfig(
                engine=engine.EngineConfig(dt=models.DT_MS, sweep=sweep,
                                           neuron_model=spec.neuron_model),
                comm_mode=mode, spike_wire=wire,
                spike_wire_remote=remote_wire)
            step, _ = make_distributed_step(net, mesh, list(spec.groups),
                                            cfg)
            state = init_stacked_state(net, list(spec.groups), sweep=sweep,
                                       neuron_model=spec.neuron_model)
            jstep = jax.jit(step)
            state, _ = jstep(state)  # compile+warm
            jax.block_until_ready(state.v_m)
            t0 = time.perf_counter()
            for _ in range(reps):
                # block EVERY rep: keeps one step's collectives in flight
                # at a time - async pile-up of N steps x M collectives can
                # deadlock the forced-host-device CPU rendezvous (sync cost
                # is noise against a ~100ms sharded step)
                state, _ = jstep(state)
                jax.block_until_ready(state.v_m)
            us = (time.perf_counter() - t0) / reps * 1e6
            overflow = int(np.asarray(state.wire_overflow).sum())
            split = wire_bytes_split(
                mode, wire, remote_wire, n_shards=net.n_shards,
                row_width=net.row_width, n_local=net.n_local,
                b_pad=net.b_pad)
            wtag = wire if remote_wire is None else f"{wire}+{remote_wire}"
            out(f"snn_wire/{mode}/{wtag}", us,
                dict(wire_bytes_step=split["intra"] + split["inter"],
                     wire_bytes_intra=split["intra"],
                     wire_bytes_inter=split["inter"],
                     mesh=f"{rows}x{width}", overflow=overflow,
                     model=spec.neuron_model, scenario=tag, sweep=sweep))


def bench_multiprocess(out, *, processes: int, devices_per_process: int,
                       backend=None, wires=("packed",),
                       comm_modes=("area",), remote_wire=None, quick=False):
    """Real multi-process step timing through the
    ``repro.launch.multihost`` launcher (N local CPU processes, gloo
    collectives, host-aligned mesh): process 0's per-step timing with the
    intra/inter-host wire-byte split.  The launcher owns all spawn/env
    mechanics (per-child XLA_FLAGS, PYTHONPATH, coordinator)."""
    import tempfile

    import repro.launch.multihost as mh_launch

    steps = 10 if quick else 40
    for mode in comm_modes:
        for wire in wires:
            with tempfile.NamedTemporaryFile(suffix=".json") as f:
                argv = ["--processes", str(processes),
                        "--devices-per-process", str(devices_per_process),
                        "--steps", str(steps), "--bench",
                        "--comm-mode", mode, "--wire", wire,
                        "--out", f.name]
                if backend:
                    argv += ["--sweep", backend]
                if remote_wire:
                    argv += ["--wire-remote", remote_wire]
                rec = mh_launch.run_launcher(
                    mh_launch.build_parser().parse_args(argv))
            tag = wire if remote_wire is None else f"{wire}+{remote_wire}"
            out(f"snn_mp/{mode}/{tag}/p{processes}", rec["us_per_step"],
                dict(processes=processes,
                     devices_per_process=devices_per_process,
                     sweep=rec["sweep"],
                     wire_bytes_intra=rec["wire_bytes_intra"],
                     wire_bytes_inter=rec["wire_bytes_inter"],
                     overflow=rec["overflow"]))


def bench_checkpoint(out, *, quick=False):
    """Checkpoint save/restore overhead (fault-tolerant runtime,
    DESIGN.md §15): a blocking save is D2H + fsync'd atomic commit, a
    restore is read + device_put + prng re-wrap.  ``ckpt_bytes`` /
    ``ckpt_leaves`` are structural (exact across machines - any drift
    means the state schema changed); ``us_per_call`` is the measured
    per-save overhead a supervised run pays every ``--save-every`` steps.
    """
    import tempfile

    from repro.checkpoint.manager import CheckpointManager, network_metadata

    scale = 0.02 if quick else 0.05
    reps = 3 if quick else 10
    spec, stdp, tag = _scenario_net(scale)
    dec = builder.decompose(spec, 1)
    g = builder.build_shards(spec, dec)[0].device_arrays()
    st = engine.init_state(g, list(spec.groups), jax.random.key(0))
    md = network_metadata(spec, seed=0, extra={"step": 0})
    with tempfile.TemporaryDirectory() as tmp:
        mgr = CheckpointManager(tmp, keep=2)
        mgr.save(0, st, metadata=md)          # warm: dirs, fs caches
        t0 = time.perf_counter()
        for i in range(reps):
            mgr.save(i + 1, st, metadata=md)
        save_us = (time.perf_counter() - t0) / reps * 1e6
        d = os.path.join(tmp, f"step_{reps:09d}")
        ckpt_bytes = sum(os.path.getsize(os.path.join(d, n))
                         for n in os.listdir(d) if n.endswith(".npy"))
        ckpt_leaves = len(jax.tree.leaves(st))
        t0 = time.perf_counter()
        for _ in range(reps):
            restored, _ = mgr.restore(st)
        jax.block_until_ready(jax.tree.leaves(restored)[0])
        rest_us = (time.perf_counter() - t0) / reps * 1e6
    shared = dict(ckpt_bytes=ckpt_bytes, ckpt_leaves=ckpt_leaves,
                  model=spec.neuron_model)
    out(f"snn_ckpt/save/{tag}/scale{scale}", save_us, shared)
    out(f"snn_ckpt/restore/{tag}/scale{scale}", rest_us, shared)


def bench_mapping_comparison(out, *, quick=False):
    """Area vs Random mapping: mirrors + spike traffic (paper Fig. 8-10)."""
    scales = (0.004,) if quick else (0.004, 0.008)
    for scale in scales:
        spec = models.marmoset(scale=scale, n_areas=4)
        for method, tag in (("area", "cortex_area"),
                            ("random", "random_equiv")):
            dec = mesh_decompose(spec, n_rows=4, row_width=2, method=method)
            net = prepare_stacked(spec, dec, 4, 2)
            shards = builder.build_shards(spec, dec)
            mem = sum(_bytes_of_shard(g) for g in shards) / len(shards)
            remote = sum(int(g.n_mirror) - int(dec.parts[i].size)
                         for i, g in enumerate(shards))
            comm = (net.comm_bytes_area if method == "area"
                    else net.comm_bytes_global)
            out(f"snn_map/{tag}/scale{scale}", mem,
                dict(remote_mirrors=remote, comm_bytes_step=comm))


_BUILD_SCALING_CODE = """
import dataclasses, json, resource, sys, time
from repro.core import builder, models

def peak_rss_mb():
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024
    except OSError:
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024

mode, scale = sys.argv[1], float(sys.argv[2])
spec, _ = models.hpc_benchmark(scale=scale, stdp=True)
spec = dataclasses.replace(spec, connectivity="procedural")
dec = builder.decompose(spec, 1)
# a forked child momentarily shares the parent's address space, so the
# kernel's RSS high-water mark starts at the HARNESS's peak, not ours -
# reset it (clear_refs code 5) so VmHWM measures this build alone
try:
    with open("/proc/self/clear_refs", "w") as f:
        f.write("5")
except OSError:
    pass
t0 = time.perf_counter()
shards = builder.build_shards(spec, dec, with_blocked=False,
                              force_materialized=(mode == "materialized"))
us = (time.perf_counter() - t0) * 1e6
print(json.dumps(dict(us=us, peak_rss_mb=round(peak_rss_mb(), 1),
                      edges=shards[0].n_edges, n_neurons=spec.n_neurons)))
"""


def bench_build_scaling(out, *, quick=False):
    """Tentpole axis (DESIGN.md §14): wall-clock + peak RSS of building the
    SAME fixed-indegree network through the materialize-then-route
    pipeline vs the procedural O(owned rows) shard-local build.  Each
    (mode, scale) runs in a fresh subprocess so ``ru_maxrss`` is that
    build's own peak, not the harness's; edge counts are identical across
    modes by construction (analytic fixed indegree), so ``edges`` is an
    exact-diffable field while the RSS/time numbers drift per machine."""
    scales = (0.1, 0.3) if quick else (0.1, 0.3, 0.6)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        p for p in (src, os.environ.get("PYTHONPATH")) if p))
    import subprocess
    for scale in scales:
        for mode in ("materialized", "procedural"):
            r = subprocess.run(
                [sys.executable, "-c", _BUILD_SCALING_CODE, mode,
                 str(scale)], env=env, capture_output=True, text=True,
                timeout=600)
            if r.returncode != 0:
                raise RuntimeError(f"build-scaling subprocess failed "
                                   f"({mode}, {scale}): {r.stderr[-2000:]}")
            rec = json.loads(r.stdout.strip().splitlines()[-1])
            out(f"snn_build/{mode}/scale{scale}", rec["us"],
                dict(edges=rec["edges"], n_neurons=rec["n_neurons"],
                     peak_rss_mb=rec["peak_rss_mb"], scale=scale))


def bench_shape_tune(out, *, quick=False):
    """Measured (PB, EB) timings for the autotuner (DESIGN.md §14): time
    the blocked sweep at each feasible candidate shape on the profile
    network, keyed by the shard's degree-distribution signature.  The
    records feed ``autotune.load_measured_timings`` /
    ``block_shapes="measured:<BENCH json>"`` - committed benchmarks become
    the tie-breaker for future builds with the same degree profile."""
    from repro.core import autotune, layout as layout_mod

    scale = 0.02 if quick else 0.1
    reps = 5 if quick else 30
    spec, _, tag = _scenario_net(scale)
    dec = builder.decompose(spec, 1)
    base = builder.build_shards(spec, dec, with_blocked=False)[0]
    sig = autotune.degree_signature(autotune.degrees_from_graphs([base]))
    rng = np.random.default_rng(0)
    ring = (rng.uniform(size=(spec.max_delay, base.n_mirror)) < 0.02) \
        .astype(np.float32)
    for pb in autotune.DEFAULT_PB_CANDIDATES:
        if pb > 4 * base.n_local:
            continue   # degenerate: whole shard in a fraction of a block
        eb = layout_mod.blocked_eb(base, pb=pb)
        g = builder.build_shards(spec, dec, block_shapes=(pb, eb))[0] \
            .device_arrays()
        backend = backends_mod.get_backend("pallas")
        lay = backend.prepare(g)
        w = backend.to_native_weights(lay, g.weight_init)
        sweep = jax.jit(lambda w, r, t: backend.sweep(lay, w, r, t))
        us = _time(sweep, (w, jnp.asarray(ring), jnp.asarray(5, jnp.int32)),
                   reps)
        out(f"shape_tune/{sig}/pb{pb}xeb{eb}", us,
            dict(pb=pb, eb=eb, edges=g.n_edges, scenario=tag, scale=scale))


def bench_gate_tune(out, *, quick=False):
    """Measured gate-capacity data for the pallas:sparse worklist
    (DESIGN.md §13): run the profile network and record, per candidate
    capacity K, the measured saturation (overflow) rate and occupancy of
    the activity gate - ``gate_tune/<signature>/cap{K}`` records keyed
    like ``shape_tune/``.  The committed records feed
    ``autotune.load_measured_gate`` / ``gate_rate="measured:<BENCH json>"``
    so future runs of a same-signature network provision the worklist from
    DATA instead of the firing-rate byte model.  The simulation is
    deterministic (fixed seed), so overflow_rate/occupancy are exact
    perf-trajectory invariants.

    Two networks are measured: the hpc verification net (the profile
    network every other tuned axis keys on) and the area-localized
    marmoset net at quick geometry - the paper's benchmark topology,
    whose exponential-distance connectivity gives the gate a very
    different indegree signature than the uniform hpc net.
    """
    from repro.core import autotune

    # LIF time-to-first-spike under the Poisson drive is ~25 ms (~250
    # steps at dt=0.1): measure the gate over a post-warmup window or
    # every record degenerates to peak_active=0
    scale, n_steps, warm = (0.05, 500, 250) if quick else (0.1, 700, 300)
    m_scale = 0.001 if quick else 0.002
    nets = [_scenario_net(scale) + (scale, "area"),
            models.get_scenario("marmoset", scale=m_scale, n_areas=4)
            + ("marmoset", m_scale, "random")]
    for spec, stdp, tag, net_scale, method in nets:
        # the marmoset net keeps its multi-area structure but lands on
        # ONE shard here (random mapping; area mapping needs >= 1 device
        # per area) - the gate only sees the merged indegree profile
        dec = builder.decompose(spec, 1, method=method)
        g = builder.build_shards(spec, dec)[0].device_arrays()
        nmodel = neuron_models_mod.get_model(spec.neuron_model)
        table = jnp.asarray(
            nmodel.make_param_table(list(spec.groups), dt=0.1))
        cfg = engine.EngineConfig(dt=0.1, stdp=stdp, sweep="flat",
                                  neuron_model=spec.neuron_model)
        sp = backends_mod.get_backend("pallas:sparse")
        lay = sp.prepare(g)
        # signature over the LAYOUT's degrees - exactly what the
        # measured-spec backend computes at gate_capacity time, so
        # records always match
        sig = autotune.degree_signature(
            autotune.degrees_from_graphs([lay]))
        nb = lay.blocked.nb
        step = engine.make_step_fn(g, table, cfg)
        n_active_fn = jax.jit(lambda r, t: sp.gate_stats(lay, r, t)[1])
        st = engine.init_state(g, list(spec.groups), jax.random.key(0),
                               sweep="flat")
        n_act = []
        t0 = time.perf_counter()
        for _ in range(n_steps):
            st, _ = step(st)
            n_act.append(int(n_active_fn(st.ring, st.t)))
        us = (time.perf_counter() - t0) * 1e6 / n_steps
        n_act = np.asarray(n_act)[warm:]
        peak = int(n_act.max())
        model_cap = autotune.gate_capacity(nb, lay.n_edges,
                                           autotune.DEFAULT_GATE_RATE)
        # candidate ladder around the observed peak (plus the model's
        # pick): below-peak points measure the overflow cost curve,
        # at/above-peak points are the zero-overflow provisioning
        # candidates
        caps = sorted({max(peak // 2, 1), max(peak, 1),
                       min(max(int(np.ceil(peak * 1.25)), peak + 1), nb),
                       model_cap})
        for cap in caps:
            out(f"gate_tune/{sig}/cap{cap}", us,
                dict(capacity=cap, nb=nb,
                     overflow_rate=round(float((n_act > cap).mean()), 4),
                     occupancy=round(float(n_act.mean() / max(cap, 1)),
                                     4),
                     peak_active=peak, n_steps=n_steps, warmup=warm,
                     scenario=tag, scale=net_scale))


def bench_surrogate(out, *, quick=False):
    """Differentiable-mode cost axes (DESIGN.md §17).

    Two questions the training subsystem's overhead story rests on:

    * **Step overhead** - surrogate mode's forward trajectory is
      bit-identical to inference, so any step-time gap is the float
      spike ring + custom-JVP dispatch, not different dynamics
      (``snn_surrogate/step/{inference,surrogate}``).
    * **Remat win** - compiled peak TEMP memory of a reverse-mode
      rollout gradient at T=200, naive scan vs chunked
      ``jax.checkpoint`` (``repro.diff.rollout``); the ``us_per_call``
      is the compiled grad's wall time, so the memory/compute trade
      rides along.  ``benchmarks/diff.py`` guards checkpointed < naive
      from the fresh run alone.
    """
    import dataclasses as dataclasses_mod

    from repro.diff import rollout as rollout_mod

    scale = 0.02 if quick else 0.05
    reps = 30 if quick else 100
    spec, _ = models.get_scenario("brunel", scale=scale)
    g = builder.build_shards(spec, builder.decompose(spec, 1))[0] \
        .device_arrays()
    nmodel = neuron_models_mod.get_model(spec.neuron_model)
    table = jnp.asarray(nmodel.make_param_table(list(spec.groups), dt=0.1))
    st0 = engine.init_state(g, list(spec.groups), jax.random.key(0))
    for mode, spike in (("inference", None),
                        ("surrogate", "fast_sigmoid")):
        cfg = engine.EngineConfig(dt=0.1, surrogate=spike,
                                  neuron_model=spec.neuron_model)
        step = engine.make_step_fn(g, table, cfg)
        us = _time(step, (st0,), reps)
        out(f"snn_surrogate/step/{mode}", us,
            dict(n_neurons=g.n_local, edges=g.n_edges, scale=scale,
                 surrogate=spike or "none"))

    n_steps = 200
    cfg = engine.EngineConfig(dt=0.1, surrogate="fast_sigmoid",
                              neuron_model=spec.neuron_model)

    def make_loss(ck):
        def loss(w):
            st = dataclasses_mod.replace(st0, weights=w)
            _, spikes = rollout_mod.rollout(st, g, table, cfg, n_steps,
                                            checkpoint_every=ck)
            return jnp.mean(spikes)
        return loss

    for label, ck in (("naive", None), ("ckpt25", 25)):
        loss = make_loss(ck)
        temp = rollout_mod.grad_peak_memory_bytes(loss, st0.weights)
        us = _time(jax.jit(jax.grad(loss)), (st0.weights,),
                   max(reps // 10, 3))
        out(f"snn_surrogate/rollout_mem/{label}", us,
            dict(temp_bytes=int(temp), n_steps=n_steps,
                 checkpoint_every=ck or 0, n_neurons=g.n_local,
                 scale=scale))


_SESSION_SOLO_CODE = """
import json, sys, time
import jax
from repro.core import builder, engine, models
from repro.core import neuron_models

seed, scale, n_steps = int(sys.argv[1]), float(sys.argv[2]), int(sys.argv[3])
t0 = time.perf_counter()
spec, stdp = models.get_scenario("brunel", scale=scale)
g = builder.build_shards(spec, builder.decompose(spec, 1))[0] \\
    .device_arrays()
nmodel = neuron_models.get_model(spec.neuron_model)
table = jax.numpy.asarray(nmodel.make_param_table(list(spec.groups),
                                                  dt=0.1))
cfg = engine.EngineConfig(dt=0.1, stdp=stdp, sweep="flat",
                          neuron_model=spec.neuron_model)
st = engine.init_state(g, list(spec.groups), jax.random.key(seed),
                       sweep="flat")
run1 = jax.jit(lambda s: engine.run(s, g, table, cfg, n_steps))
_, bits = run1(st)
jax.block_until_ready(bits)
print(json.dumps(dict(s=time.perf_counter() - t0)))
"""


def bench_sessions(out, *, quick=False, n_sessions=8):
    """Multi-tenant serving throughput (DESIGN.md §16): N brunel sessions
    resident in ONE vmapped slot batch (one build, one compile, shared
    consts) vs the same N seeds run as sequential one-shot scripts (each
    paying its own build + jit + scan - today's batch-script workflow).
    Each one-shot run is a FRESH subprocess (the bench_build_scaling
    idiom): an in-process loop of fresh ``jax.jit`` closures undercounts
    the baseline because later compiles hit XLA's in-process caches that
    a real batch script never sees.  The sequential cost is the child's
    full wall-clock (interpreter + imports + build + compile + run - what
    ``python run_one.py`` actually costs); the child also reports its
    post-import compute seconds, recorded as ``seq_compute_s`` with the
    compute-only ratio in ``speedup_vs_sequential_compute`` so both
    accountings are visible.  The acceptance bar is the
    ``speedup_vs_sequential`` field of the batched record: >= 4x
    aggregate steps/sec at N = 8."""
    import subprocess

    from repro.serve.snn import SessionEngine

    scale = 0.01 if quick else 0.02
    n_steps = 50 if quick else 100

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env = dict(os.environ, PYTHONPATH=os.pathsep.join(
        p for p in (src, os.environ.get("PYTHONPATH")) if p))
    seq_s = 0.0       # wall-clock of the one-shot processes
    seq_compute = 0.0  # post-import build+compile+run inside the child
    for seed in range(n_sessions):
        t0 = time.perf_counter()
        r = subprocess.run(
            [sys.executable, "-c", _SESSION_SOLO_CODE, str(seed),
             str(scale), str(n_steps)], env=env, capture_output=True,
            text=True, timeout=600)
        seq_s += time.perf_counter() - t0
        if r.returncode != 0:
            raise RuntimeError(f"solo-session subprocess failed "
                               f"(seed {seed}): {r.stderr[-2000:]}")
        seq_compute += json.loads(r.stdout.strip().splitlines()[-1])["s"]

    t0 = time.perf_counter()
    eng = SessionEngine(max_sessions=n_sessions, sweep="flat")
    for seed in range(n_sessions):
        eng.create("brunel", seed=seed, scale=scale)
    eng.step_wave(n=n_steps)
    ses_s = time.perf_counter() - t0

    total = n_sessions * n_steps
    seq_sps, ses_sps = total / seq_s, total / ses_s
    out(f"snn_sessions/sequential/s{n_sessions}", seq_s * 1e6 / total,
        dict(n_sessions=n_sessions, n_steps=n_steps,
             agg_steps_per_sec=round(seq_sps, 1),
             seq_compute_s=round(seq_compute, 2), scenario="brunel",
             scale=scale))
    out(f"snn_sessions/batched/s{n_sessions}", ses_s * 1e6 / total,
        dict(n_sessions=n_sessions, n_steps=n_steps,
             agg_steps_per_sec=round(ses_sps, 1),
             speedup_vs_sequential=round(ses_sps / seq_sps, 2),
             speedup_vs_sequential_compute=round(seq_compute / ses_s, 2),
             scenario="brunel", scale=scale))


def main(out, backend: str | None = None, *, wires=DEFAULT_WIRES,
         comm_modes=DEFAULT_COMM_MODES, remote_wire=None,
         processes: int | None = None, devices_per_process: int = 2,
         quick: bool = False, profile: bool = False, model: str = "lif",
         scenario: str | None = None, ckpt: bool = False,
         sessions: int | None = None, gate_tune: bool = False,
         surrogate: bool = False):
    if sessions:
        # multi-tenant serving axis only: batched vs sequential throughput
        bench_sessions(out, quick=quick, n_sessions=sessions)
        return
    if gate_tune:
        # measured gate-capacity records only (pallas:sparse provisioning)
        bench_gate_tune(out, quick=quick)
        return
    if surrogate:
        # differentiable-mode axis only: surrogate step overhead + the
        # checkpointed-rollout gradient memory trade (DESIGN.md §17)
        bench_surrogate(out, quick=quick)
        return
    if ckpt:
        # checkpoint save/restore overhead only (fault-tolerance axis)
        bench_checkpoint(out, quick=quick)
        return
    if profile:
        # per-phase breakdown mode (sweep / neuron_update / stdp /
        # exchange) - the hot-path drill-down, instead of the scaling axes,
        # plus the dense-vs-gated activity sweep (the pallas:sparse axis)
        bench_profile(out, (backend,) if backend else DEFAULT_BACKENDS,
                      quick=quick, model=model, scenario=scenario)
        bench_gate_activity(out, quick=quick)
        return
    if processes:
        # multi-process axis only: real cross-process collectives through
        # the repro.launch.multihost launcher
        bench_multiprocess(out, processes=processes,
                           devices_per_process=devices_per_process,
                           backend=backend, wires=wires,
                           comm_modes=comm_modes,
                           remote_wire=remote_wire, quick=quick)
        return
    bench_step_scaling(out, (backend,) if backend else DEFAULT_BACKENDS,
                       quick=quick, model=model, scenario=scenario)
    bench_wire_exchange(out, wires, comm_modes, remote_wire=remote_wire,
                        quick=quick, model=model, scenario=scenario,
                        backend=backend)
    bench_mapping_comparison(out, quick=quick)
    bench_build_scaling(out, quick=quick)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(
        description="SNN engine scaling benchmark with backend, spike-wire "
                    "and comm-mode axes")
    ap.add_argument("--backend", default=None,
                    help="restrict the step benchmark to one execution "
                         "backend (any registered name or variant: flat|"
                         "bucketed|pallas|pallas:auto|pallas:sparse|"
                         "pallas:sparse:<rate>; default: all registered)")
    ap.add_argument("--model", default="lif",
                    help="NeuronModel registry axis (lif|izhikevich|adex|"
                         "poisson): run the cross-model demo network with "
                         "these dynamics; records carry the model name")
    ap.add_argument("--scenario", default=None,
                    help="scenario-zoo network for the step/wire benches "
                         "(hpc_benchmark|brunel|microcircuit|marmoset); "
                         "overrides --model's demo net")
    ap.add_argument("--spike-wire", default=None,
                    help="restrict the wire benchmark to one codec "
                         "(f32|u8|packed|sparse|sparse:<rate>; default: "
                         "all registered)")
    ap.add_argument("--spike-wire-remote", default=None,
                    help="codec for the cross-row boundary tier (the "
                         "inter-host hop) - e.g. packed intra + sparse "
                         "inter; default: same as --spike-wire")
    ap.add_argument("--comm-mode", default=None,
                    choices=DEFAULT_COMM_MODES,
                    help="restrict the wire benchmark to one comm mode "
                         "(default: area and global)")
    ap.add_argument("--processes", type=int, default=None,
                    help="run the wire benchmark across N REAL local "
                         "processes via the repro.launch.multihost "
                         "launcher (skips the in-process axes)")
    ap.add_argument("--devices-per-process", type=int, default=2,
                    help="forced host devices per process for --processes")
    ap.add_argument("--ckpt", action="store_true",
                    help="checkpoint save/restore overhead only "
                         "(fault-tolerant runtime axis, DESIGN.md §15)")
    ap.add_argument("--sessions", type=int, default=None, metavar="N",
                    help="multi-tenant serving axis only: N resident "
                         "sessions through ONE vmapped slot batch vs N "
                         "sequential one-shot runs (DESIGN.md §16)")
    ap.add_argument("--gate-tune", action="store_true",
                    help="measured gate-capacity records only "
                         "(gate_tune/<sig>/cap{K}: overflow rate + "
                         "occupancy per candidate worklist capacity)")
    ap.add_argument("--surrogate", action="store_true",
                    help="differentiable-mode axis only: surrogate vs "
                         "inference step overhead + naive vs checkpointed "
                         "rollout gradient peak memory at T=200")
    ap.add_argument("--quick", action="store_true",
                    help="tiny config: smallest scales, few reps (CI smoke)")
    ap.add_argument("--profile", action="store_true",
                    help="per-phase hot-path breakdown (sweep / "
                         "neuron_update / stdp / exchange) instead of the "
                         "scaling axes; JSON records carry a 'phase' field")
    ap.add_argument("--json", default="experiments/bench_snn.json",
                    help="write records (incl. wire bytes/step) as JSON; "
                         "'' disables")
    args = ap.parse_args()
    # fail fast, before the step-scaling phase runs
    neuron_models_mod.get_model(args.model)
    if args.backend:
        backends_mod.get_backend(args.backend)
    if args.scenario and args.scenario not in models.available_scenarios():
        ap.error(f"unknown --scenario {args.scenario!r}; available: "
                 f"{models.available_scenarios()}")
    if args.spike_wire:
        from repro.core.wire import get_wire
        get_wire(args.spike_wire)
    if args.spike_wire_remote:
        from repro.core.wire import get_wire
        get_wire(args.spike_wire_remote)

    records = []

    def _out(name, us, derived=None):
        derived = derived or {}
        records.append(dict(name=name, us_per_call=round(us, 2), **derived))
        extra = ";".join(f"{k}={v}" for k, v in derived.items())
        print(f"{name},{us:.2f},{extra}", flush=True)

    print("name,us_per_call,derived")
    main(_out, args.backend,
         wires=(args.spike_wire,) if args.spike_wire
         else (("packed",) if args.processes else DEFAULT_WIRES),
         comm_modes=(args.comm_mode,) if args.comm_mode
         else (("area",) if args.processes else DEFAULT_COMM_MODES),
         remote_wire=args.spike_wire_remote,
         processes=args.processes,
         devices_per_process=args.devices_per_process,
         quick=args.quick, profile=args.profile,
         model=args.model, scenario=args.scenario, ckpt=args.ckpt,
         sessions=args.sessions, gate_tune=args.gate_tune,
         surrogate=args.surrogate)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"-> {args.json}")

"""Pretty-print the §Roofline table from experiments/roofline.json."""

import json
import os


def main(out=None, path="experiments/roofline.json"):
    if not os.path.exists(path):
        print(f"(no {path}; run PYTHONPATH=src python -m "
              "repro.launch.roofline first)")
        return
    with open(path) as f:
        rows = json.load(f)
    hdr = (f"{'arch':22s} {'shape':12s} {'dom':10s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'useful':>7s} "
           f"{'roofline':>8s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']:22s} {r['shape']:12s} {r['status']}")
            continue
        print(f"{r['arch']:22s} {r['shape']:12s} {r['dominant']:10s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['useful_fraction']:7.2f} "
              f"{r['roofline_fraction']:8.3f}")
        if out is not None:
            out(f"roofline/{r['arch']}/{r['shape']}",
                max(r['compute_s'], r['memory_s'], r['collective_s']) * 1e6,
                f"dom={r['dominant']};roofline={r['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()

"""Per-arch smoke-scale step benchmarks + serving throughput.

Wall times at smoke scale verify every family's step functions execute and
give a relative cost fingerprint; TPU-scale cost is covered by §Roofline
(static analysis), not by these CPU timings.
"""

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import TrainConfig
from repro.models.model import build_model
from repro.serve.engine import BatchServer
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state

FAST_ARCHS = ("qwen2.5-3b", "internlm2-1.8b", "rwkv6-3b",
              "qwen3-moe-30b-a3b", "whisper-tiny")


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s + 1), 1,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq,
                                                  cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (b, cfg.n_prefix_embeds,
                                                   cfg.d_model)) * 0.02
    return batch


def bench_train_steps(out):
    for arch in FAST_ARCHS:
        cfg = configs.get_smoke(arch)
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        tcfg = TrainConfig(optimizer="adamw", lr=1e-3)
        opt = init_opt_state(tcfg, params)
        step = jax.jit(make_train_step(m, tcfg), donate_argnums=(0, 1))
        batch = _batch(cfg, jax.random.key(1))
        params, opt, met = step(params, opt, batch, jnp.asarray(0))
        jax.block_until_ready(met["loss"])
        n = 10
        t0 = time.perf_counter()
        for i in range(n):
            params, opt, met = step(params, opt, batch, jnp.asarray(i))
        jax.block_until_ready(met["loss"])
        us = (time.perf_counter() - t0) / n * 1e6
        out(f"train_step_smoke/{arch}", us, f"loss={float(met['loss']):.3f}")


def bench_serving(out):
    cfg = configs.get_smoke("qwen2.5-3b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    server = BatchServer(m, params, slots=4, max_len=64, eos_id=-1)
    reqs = [[5, 6, 7], [8, 9, 10, 11], [3], [12, 13]]
    outs, stats = server.serve(reqs, max_new_tokens=16)
    out("serve/decode_tok_per_s", stats.decode_tok_per_s * 1e0,
        f"prefill_s={stats.prefill_s:.3f};tokens={stats.tokens_out}")


def main(out):
    bench_train_steps(out)
    bench_serving(out)

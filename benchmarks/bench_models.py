"""Per-arch smoke-scale step benchmarks + serving throughput + the SNN
scenario zoo.

Wall times at smoke scale verify every family's step functions execute and
give a relative cost fingerprint; TPU-scale cost is covered by §Roofline
(static analysis), not by these CPU timings.  The scenario rows do the
same for the CORTEX engine's scenario zoo (repro.core.models) x neuron
models (DESIGN.md §12): every registered workload steps end-to-end.
"""

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import TrainConfig
from repro.models.model import build_model
from repro.serve.engine import BatchServer
from repro.train.loop import make_train_step
from repro.train.optimizer import init_opt_state

FAST_ARCHS = ("qwen2.5-3b", "internlm2-1.8b", "rwkv6-3b",
              "qwen3-moe-30b-a3b", "whisper-tiny")


def _batch(cfg, key, b=2, s=16):
    batch = {"tokens": jax.random.randint(key, (b, s + 1), 1,
                                          cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, cfg.encoder_seq,
                                                  cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(key, (b, cfg.n_prefix_embeds,
                                                   cfg.d_model)) * 0.02
    return batch


def bench_train_steps(out):
    for arch in FAST_ARCHS:
        cfg = configs.get_smoke(arch)
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        tcfg = TrainConfig(optimizer="adamw", lr=1e-3)
        opt = init_opt_state(tcfg, params)
        step = jax.jit(make_train_step(m, tcfg), donate_argnums=(0, 1))
        batch = _batch(cfg, jax.random.key(1))
        params, opt, met = step(params, opt, batch, jnp.asarray(0))
        jax.block_until_ready(met["loss"])
        n = 10
        t0 = time.perf_counter()
        for i in range(n):
            params, opt, met = step(params, opt, batch, jnp.asarray(i))
        jax.block_until_ready(met["loss"])
        us = (time.perf_counter() - t0) / n * 1e6
        out(f"train_step_smoke/{arch}", us, f"loss={float(met['loss']):.3f}")


def bench_serving(out):
    cfg = configs.get_smoke("qwen2.5-3b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    server = BatchServer(m, params, slots=4, max_len=64, eos_id=-1)
    reqs = [[5, 6, 7], [8, 9, 10, 11], [3], [12, 13]]
    outs, stats = server.serve(reqs, max_new_tokens=16)
    out("serve/decode_tok_per_s", stats.decode_tok_per_s * 1e0,
        f"prefill_s={stats.prefill_s:.3f};tokens={stats.tokens_out}")


def bench_snn_scenarios(out):
    """Scenario-zoo step timings: one engine step fingerprint per
    registered scenario and per neuron model's demo network."""
    import numpy as np

    from repro.core import builder, engine, models
    from repro.core import neuron_models as neuron_models_mod

    cells = [(f"scenario/{name}",) + models.get_scenario(name)
             for name in models.available_scenarios()]
    cells += [(f"model/{m}",) + models.model_demo(m, scale=0.01)
              for m in ("lif", "izhikevich", "adex", "poisson")]
    for tag, spec, stdp in cells:
        nmodel = neuron_models_mod.get_model(spec.neuron_model)
        # multi-area specs need >= 1 device per area under area mapping;
        # this is a 1-shard fingerprint, so fall back to random there
        method = "random" if len(spec.areas) > 1 else "area"
        g = builder.build_shards(
            spec, builder.decompose(spec, 1, method=method))[0] \
            .device_arrays()
        table = nmodel.make_param_table(list(spec.groups), dt=0.1)
        cfg = engine.EngineConfig(dt=0.1, stdp=stdp,
                                  neuron_model=spec.neuron_model)
        st = engine.init_state(g, list(spec.groups), jax.random.key(0),
                               neuron_model=spec.neuron_model)
        step = engine.make_step_fn(g, table, cfg)
        st, _ = step(st)
        n = 10
        spiked = 0
        t0 = time.perf_counter()
        for _ in range(n):
            st, bits = step(st)
            spiked += int(np.asarray(bits).sum())
        jax.block_until_ready(st.neurons.v_m)
        us = (time.perf_counter() - t0) / n * 1e6
        out(f"snn_{tag}", us,
            f"n={spec.n_neurons};edges={g.n_edges};"
            f"model={spec.neuron_model};spiked={spiked}")


def main(out):
    bench_train_steps(out)
    bench_serving(out)
    bench_snn_scenarios(out)

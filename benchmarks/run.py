"""Benchmark harness entry point - one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus the roofline table if
experiments/roofline.json exists).

    PYTHONPATH=src python -m benchmarks.run [--only snn|kernels|models]

``--json`` switches to the committed perf-trajectory mode: it runs the
curated baseline suite (per-phase profile + dense-vs-gated activity sweep
+ step scaling + wire exchange, backend x wire x model incl.
pallas:sparse) and writes ``BENCH_<scale>.json`` - the file CI diffs
fresh runs against (``benchmarks/diff.py``).  ``--scale full`` is the
committed-numbers configuration (largest feasible single-shard geometry
on this CPU interpret proxy); ``--scale quick`` is the CI-sized one.
"""

import argparse
import sys


def _out(name: str, us: float, derived="") -> None:
    if isinstance(derived, dict):
        derived = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.2f},{derived}", flush=True)


def _bench_json(path: str, scale: str) -> None:
    import json
    import os
    import platform

    import jax

    from benchmarks import bench_snn

    quick = scale == "quick"
    records = []

    def out(name, us, derived=None):
        rec = dict(name=name, us_per_call=round(us, 2), **(derived or {}))
        if name.startswith("snn_step/") or name.startswith("snn_gate/"):
            rec["steps_per_sec"] = round(1e6 / us, 2) if us > 0 else None
        records.append(rec)
        _out(name, us, derived or {})

    print("name,us_per_call,derived")
    # per-phase hot path (every backend incl. pallas:sparse) + the
    # dense-vs-gated activity axis (the pallas:sparse acceptance metric)
    bench_snn.bench_profile(out, quick=quick)
    bench_snn.bench_gate_activity(out, quick=quick)
    # steps/sec scaling, backend axis
    bench_snn.bench_step_scaling(out, quick=quick)
    # one cross-model leg (backend x model)
    bench_snn.bench_step_scaling(out, ("pallas", "pallas:sparse"),
                                 quick=True, model="izhikevich")
    # wire codecs with the intra/inter byte split (backend x wire)
    bench_snn.bench_wire_exchange(out, comm_modes=("area",), quick=quick)
    bench_snn.bench_mapping_comparison(out, quick=quick)
    # build scaling: materialized vs procedural wall-clock + peak RSS
    # (fresh subprocess per point); diff.py holds procedural's peak
    # strictly below materialized at the largest common scale
    bench_snn.bench_build_scaling(out, quick=quick)
    # measured (PB, EB) sweep timings keyed by degree signature - the
    # committed records ARE the autotuner's measured tie-breaker
    # (block_shapes="measured:BENCH_full.json")
    bench_snn.bench_shape_tune(out, quick=quick)
    # checkpoint save/restore overhead (fault-tolerant runtime,
    # DESIGN.md §15); ckpt_bytes/ckpt_leaves are structural guards
    bench_snn.bench_checkpoint(out, quick=quick)
    # measured gate-capacity records (pallas:sparse worklist provisioning
    # from data: gate_rate="measured:BENCH_full.json"); the deterministic
    # overflow_rate/occupancy fields are exact invariants
    bench_snn.bench_gate_tune(out, quick=quick)
    # differentiable-mode costs (DESIGN.md §17): surrogate vs inference
    # step overhead + naive vs checkpointed rollout gradient peak memory;
    # diff.py holds checkpointed temp bytes strictly below naive at T=200
    bench_snn.bench_surrogate(out, quick=quick)
    # multi-tenant serving throughput: N resident sessions in ONE vmapped
    # slot batch vs N sequential one-shot runs (DESIGN.md §16);
    # diff.py holds the batched speedup_vs_sequential above its floor
    bench_snn.bench_sessions(out, quick=quick)

    payload = {
        "meta": {
            "scale": scale,
            "jax": jax.__version__,
            "backend_platform": jax.default_backend(),
            "python": platform.python_version(),
            "machine": platform.machine(),
        },
        "records": records,
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    print(f"-> {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "snn", "kernels", "models", "roofline"])
    ap.add_argument("--json", default=None, nargs="?", const="",
                    metavar="PATH",
                    help="perf-trajectory mode: run the curated baseline "
                         "suite and write BENCH_<scale>.json (or PATH)")
    ap.add_argument("--scale", default="quick", choices=["quick", "full"],
                    help="baseline suite size for --json (quick: CI-sized; "
                         "full: committed-numbers geometry)")
    args = ap.parse_args()

    if args.json is not None:
        _bench_json(args.json or f"BENCH_{args.scale}.json", args.scale)
        return

    print("name,us_per_call,derived")
    if args.only in (None, "snn"):
        from benchmarks import bench_snn
        bench_snn.main(_out)
    if args.only in (None, "kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main(_out)
    if args.only in (None, "models"):
        from benchmarks import bench_models
        bench_models.main(_out)
    if args.only in (None, "roofline"):
        from benchmarks import roofline_table
        roofline_table.main(_out)


if __name__ == "__main__":
    main()

"""Benchmark harness entry point - one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus the roofline table if
experiments/roofline.json exists).

    PYTHONPATH=src python -m benchmarks.run [--only snn|kernels|models]
"""

import argparse
import sys


def _out(name: str, us: float, derived="") -> None:
    if isinstance(derived, dict):
        derived = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us:.2f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=[None, "snn", "kernels", "models", "roofline"])
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.only in (None, "snn"):
        from benchmarks import bench_snn
        bench_snn.main(_out)
    if args.only in (None, "kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main(_out)
    if args.only in (None, "models"):
        from benchmarks import bench_models
        bench_models.main(_out)
    if args.only in (None, "roofline"):
        from benchmarks import roofline_table
        roofline_table.main(_out)


if __name__ == "__main__":
    main()
